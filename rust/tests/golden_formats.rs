//! Golden-vector pinning: the jnp quantizers (L1/L2 semantics) must be
//! bit-identical to the rust `formats::` implementations. Produced by
//! `python -m compile.aot` (requires `make artifacts` — tests skip with
//! a note if the artifacts are absent).

use floatsd_lstm::formats::{round_f16, round_f8, round_sd8, FLOAT_SD8};
use floatsd_lstm::qmath::qsigmoid::{sigmoid_sd8, sigmoid_sd8_one_region, tanh_fp8};
use floatsd_lstm::tensorfile::read_tensors;

fn golden() -> Option<std::collections::HashMap<String, (Vec<usize>, Vec<f32>)>> {
    let path = std::path::Path::new("artifacts/golden/formats.tensors");
    if !path.exists() {
        eprintln!("SKIP: {path:?} missing (run `make artifacts`)");
        return None;
    }
    let tensors = read_tensors(path).expect("read golden");
    Some(
        tensors
            .into_iter()
            .map(|t| {
                let data = t.as_f32().expect("golden tensors are f32");
                (t.name, (t.shape, data))
            })
            .collect(),
    )
}

#[test]
fn sd8_grid_matches_python() {
    let Some(g) = golden() else { return };
    let (_, grid) = &g["sd8_grid"];
    assert_eq!(grid.len(), FLOAT_SD8.values().len());
    for (a, b) in grid.iter().zip(FLOAT_SD8.values()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn elementwise_quantizers_bit_exact() {
    let Some(g) = golden() else { return };
    let (_, xs) = &g["x"];
    let checks: [(&str, fn(f32) -> f32); 4] = [
        ("fp8", round_f8),
        ("fp16", round_f16),
        ("sd8", round_sd8),
        ("sig2", sigmoid_sd8),
    ];
    for (name, f) in checks {
        let (_, want) = &g[name];
        let mut mismatches = 0;
        for (i, (&x, &w)) in xs.iter().zip(want).enumerate() {
            let got = f(x);
            if got.to_bits() != w.to_bits() {
                // -0.0 vs 0.0 is an acceptable representation difference
                if got == 0.0 && w == 0.0 {
                    continue;
                }
                mismatches += 1;
                if mismatches < 5 {
                    eprintln!("{name}[{i}] x={x}: rust {got} vs jnp {w}");
                }
            }
        }
        assert_eq!(mismatches, 0, "{name}: {mismatches} mismatches");
    }
}

#[test]
fn one_region_sigmoid_matches() {
    let Some(g) = golden() else { return };
    let (_, xs) = &g["x"];
    let (_, want) = &g["sig1"];
    for (&x, &w) in xs.iter().zip(want) {
        let got = sigmoid_sd8_one_region(x);
        assert!(
            got.to_bits() == w.to_bits() || (got == 0.0 && w == 0.0),
            "x={x}: rust {got} vs jnp {w}"
        );
    }
}

#[test]
fn lstm_gates_match_python_reference() {
    let Some(g) = golden() else { return };
    let (zf, zi, zo, zg, c) = (
        &g["g_zf"].1, &g["g_zi"].1, &g["g_zo"].1, &g["g_zg"].1, &g["g_c"].1,
    );
    let (want_c, want_h) = (&g["g_c_out"].1, &g["g_h_out"].1);
    for i in 0..zf.len() {
        // mirror ref.ref_lstm_gates exactly (c rounded to fp16 at entry,
        // f32 product-sum, fp16 round)
        let cp = round_f16(c[i]);
        let f = sigmoid_sd8(zf[i]);
        let ii = sigmoid_sd8(zi[i]);
        let o = sigmoid_sd8(zo[i]);
        let gg = round_f8(zg[i].tanh());
        let c_new = round_f16(f * cp + ii * gg);
        let h_new = round_f8(o * tanh_fp8(c_new));
        assert_eq!(c_new.to_bits(), want_c[i].to_bits(), "c[{i}]");
        assert_eq!(h_new.to_bits(), want_h[i].to_bits(), "h[{i}]");
    }
}

#[test]
fn qmatmul_close_to_python() {
    // jnp accumulates the dot in f32 with backend-defined order; the
    // rust engine uses the hardware's exact-group discipline, so we
    // allow ≤ 1 fp16 ulp (DESIGN.md §6 fidelity note).
    let Some(g) = golden() else { return };
    let (xsh, x) = &g["mm_x"];
    let (wsh, w) = &g["mm_w"];
    let (_, want) = &g["mm_y"];
    let (m, k, n) = (xsh[0], xsh[1], wsh[1]);

    // model-mirror: f64 exact dot of quantized operands, single f16 round
    let mut worst = 0i32;
    for r in 0..m {
        for cn in 0..n {
            let mut acc = 0f64;
            for kk in 0..k {
                acc += round_f8(x[r * k + kk]) as f64 * round_sd8(w[kk * n + cn]) as f64;
            }
            let got = floatsd_lstm::formats::Fp16::from_f64(acc);
            let wv = floatsd_lstm::formats::Fp16::from_f32(want[r * n + cn]);
            let d = (got.0 as i32 - wv.0 as i32).abs();
            worst = worst.max(d);
            assert!(d <= 1, "({r},{cn}): rust {} vs jnp {} ({d} ulp)", got.to_f32(), wv.to_f32());
        }
    }
    eprintln!("qmatmul worst fp16 ulp distance: {worst}");
}
