//! The serving engine's batching contract, pinned: batched kernels are
//! **bit-identical** to the sequential per-stream path at every level —
//! cell step, stack step, ragged lockstep forward, and the full
//! scheduler/worker/session server — including hidden sizes that are
//! not a multiple of `MAC_GROUP` and sessions of different lengths.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use floatsd_lstm::formats::round_f8;
use floatsd_lstm::lstm::cell::{BatchScratch, CellScratch, QLstmCell};
use floatsd_lstm::lstm::{synthetic_stack, QLstmStack};
use floatsd_lstm::rng::SplitMix64;
use floatsd_lstm::serve::{ServeConfig, Server};
use floatsd_lstm::testing::{property, Gen};
use floatsd_lstm::train::{CellGrads, CellTape};

fn rand_cell(d: usize, hidden: usize, seed: u64) -> QLstmCell {
    let mut rng = SplitMix64::new(seed);
    let wx: Vec<f32> = (0..d * 4 * hidden).map(|_| rng.uniform(-0.4, 0.4)).collect();
    let wh: Vec<f32> = (0..hidden * 4 * hidden).map(|_| rng.uniform(-0.4, 0.4)).collect();
    let b: Vec<f32> = (0..4 * hidden).map(|_| rng.uniform(-0.1, 0.1)).collect();
    QLstmCell::from_jax_layout(d, hidden, &wx, &wh, &b)
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: batched {x} vs sequential {y}");
    }
}

/// `step_batch` over B interleaved streams == B independent `step`
/// loops, bit for bit — across hidden sizes straddling MAC_GROUP
/// boundaries (5, 7, 13 are not multiples of 4).
#[test]
fn cell_step_batch_matches_independent_steps() {
    for &(d, hidden) in &[(3usize, 5usize), (6, 7), (4, 8), (6, 13)] {
        for &batch in &[1usize, 2, 5, 8] {
            let cell = rand_cell(d, hidden, (d * 100 + hidden) as u64);
            let mut rng = SplitMix64::new(batch as u64 + 1);
            let t_len = 12;
            // per-stream input sequences on the FP8 grid
            let inputs: Vec<Vec<Vec<f32>>> = (0..batch)
                .map(|_| {
                    (0..t_len)
                        .map(|_| (0..d).map(|_| round_f8(rng.uniform(-2.0, 2.0))).collect())
                        .collect()
                })
                .collect();

            // sequential reference: each stream alone
            let mut ref_h = vec![vec![0f32; hidden]; batch];
            let mut ref_c = vec![vec![0f32; hidden]; batch];
            let mut scratch = CellScratch::new(hidden);
            for b in 0..batch {
                for t in 0..t_len {
                    cell.step(&inputs[b][t], &mut ref_h[b], &mut ref_c[b], &mut scratch);
                }
            }

            // batched: all streams in lockstep through flat buffers
            let mut hs = vec![0f32; batch * hidden];
            let mut cs = vec![0f32; batch * hidden];
            let mut bscratch = BatchScratch::new(hidden, batch);
            let mut xs = vec![0f32; batch * d];
            for t in 0..t_len {
                for b in 0..batch {
                    xs[b * d..(b + 1) * d].copy_from_slice(&inputs[b][t]);
                }
                cell.step_batch(&xs, &mut hs, &mut cs, batch, &mut bscratch);
            }

            for b in 0..batch {
                let what = format!("h (d={d} H={hidden} B={batch} stream={b})");
                assert_bits_eq(&hs[b * hidden..(b + 1) * hidden], &ref_h[b], &what);
                let what = format!("c (d={d} H={hidden} B={batch} stream={b})");
                assert_bits_eq(&cs[b * hidden..(b + 1) * hidden], &ref_c[b], &what);
            }
        }
    }
}

/// The training mirror of the forward contract: `backward_batch` over
/// B sequences is bit-identical to B independent `backward` calls —
/// parameter gradients (folded in stream order with
/// `CellGrads::add_assign`, the documented reduction contract) AND the
/// propagated per-step input cotangents. Covers hidden sizes off the
/// MAC_GROUP grid and the trivial B=1 case.
#[test]
fn cell_backward_batch_matches_independent_backward() {
    for &(d, hidden) in &[(3usize, 5usize), (4, 8), (6, 7)] {
        for &batch in &[1usize, 3, 5] {
            let cell = rand_cell(d, hidden, (d * 1000 + hidden) as u64);
            let mut rng = SplitMix64::new(100 + batch as u64);
            let t_len = 6;
            // per-stream FP8 inputs and incoming FP8 cotangents
            let inputs: Vec<Vec<Vec<f32>>> = (0..batch)
                .map(|_| {
                    (0..t_len)
                        .map(|_| (0..d).map(|_| round_f8(rng.uniform(-2.0, 2.0))).collect())
                        .collect()
                })
                .collect();
            let dhs: Vec<Vec<Vec<f32>>> = (0..batch)
                .map(|_| {
                    (0..t_len)
                        .map(|_| {
                            (0..hidden).map(|_| round_f8(rng.uniform(-0.5, 0.5))).collect()
                        })
                        .collect()
                })
                .collect();

            // independent per-stream reference: trace + backward, fold
            // grads in stream order
            let mut ref_grads = CellGrads::zeros(&cell);
            let mut ref_dx: Vec<Vec<Vec<f32>>> = Vec::new();
            for b in 0..batch {
                let mut h = vec![0f32; hidden];
                let mut c = vec![0f32; hidden];
                let mut scr = BatchScratch::new(hidden, 1);
                let mut tape = CellTape::new(1, d, hidden);
                for t in 0..t_len {
                    cell.step_traced(&inputs[b][t], &mut h, &mut c, &mut scr, &mut tape);
                }
                let mut g = CellGrads::zeros(&cell);
                let dx = cell.backward(&tape, &dhs[b], &mut g);
                ref_grads.add_assign(&g);
                ref_dx.push(dx);
            }

            // batched: same streams in lockstep through flat buffers
            let mut hs = vec![0f32; batch * hidden];
            let mut cs = vec![0f32; batch * hidden];
            let mut scr = BatchScratch::new(hidden, batch);
            let mut tape = CellTape::new(batch, d, hidden);
            let mut xs = vec![0f32; batch * d];
            for t in 0..t_len {
                for b in 0..batch {
                    xs[b * d..(b + 1) * d].copy_from_slice(&inputs[b][t]);
                }
                cell.step_batch_traced(&xs, &mut hs, &mut cs, batch, &mut scr, &mut tape);
            }
            let dh_seq: Vec<Vec<f32>> = (0..t_len)
                .map(|t| {
                    let mut flat = vec![0f32; batch * hidden];
                    for b in 0..batch {
                        flat[b * hidden..(b + 1) * hidden].copy_from_slice(&dhs[b][t]);
                    }
                    flat
                })
                .collect();
            let mut grads = CellGrads::zeros(&cell);
            let dx_seq = cell.backward_batch(&tape, &dh_seq, &mut grads);

            let what = format!("d={d} H={hidden} B={batch}");
            assert_bits_eq(&grads.dwx, &ref_grads.dwx, &format!("dwx ({what})"));
            assert_bits_eq(&grads.dwh, &ref_grads.dwh, &format!("dwh ({what})"));
            assert_bits_eq(&grads.db, &ref_grads.db, &format!("db ({what})"));
            for t in 0..t_len {
                for b in 0..batch {
                    assert_bits_eq(
                        &dx_seq[t][b * d..(b + 1) * d],
                        &ref_dx[b][t],
                        &format!("dx ({what} t={t} stream={b})"),
                    );
                }
            }
        }
    }
}

fn ragged_seqs(n: usize, vocab: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|_| {
            let len = 1 + rng.next_below(15) as usize;
            (0..len).map(|_| rng.next_below(vocab as u64) as usize).collect()
        })
        .collect()
}

/// `forward_batch` over ragged sessions == independent `forward` calls.
#[test]
fn stack_forward_batch_matches_forward_ragged() {
    // hidden 5 and 10: one below, one above a MAC_GROUP multiple; one
    // and two layers
    for &(hidden, layers) in &[(5usize, 1usize), (10, 2)] {
        let vocab = 32;
        let stack = synthetic_stack(vocab, 6, hidden, layers, vocab, 77);
        let seqs = ragged_seqs(9, vocab, hidden as u64);
        let refs: Vec<&[usize]> = seqs.iter().map(Vec::as_slice).collect();

        let batched = stack.forward_batch(&refs);
        for (i, seq) in seqs.iter().enumerate() {
            let sequential = stack.forward(seq);
            assert_eq!(batched[i].len(), sequential.len(), "stream {i}: step count");
            for (t, (bt, st)) in batched[i].iter().zip(&sequential).enumerate() {
                assert_bits_eq(bt, st, &format!("logits (H={hidden} L={layers} s={i} t={t})"));
            }
        }
    }
}

/// Property sweep: random topologies and ragged batches stay bit-exact.
#[test]
fn property_random_topologies_batch_equals_sequential() {
    property("forward_batch == forward", 25, |g: &mut Gen| {
        let vocab = 8 + g.usize_below(24);
        let dim = 2 + g.usize_below(6);
        let hidden = 3 + g.usize_below(10); // covers non-multiples of 4
        let layers = 1 + g.usize_below(2);
        let stack = synthetic_stack(vocab, dim, hidden, layers, vocab, g.seed);
        let n = 1 + g.usize_below(6);
        let seqs: Vec<Vec<usize>> = (0..n)
            .map(|_| (0..1 + g.usize_below(8)).map(|_| g.usize_below(vocab)).collect())
            .collect();
        let refs: Vec<&[usize]> = seqs.iter().map(Vec::as_slice).collect();
        let batched = stack.forward_batch(&refs);
        for (i, seq) in seqs.iter().enumerate() {
            let sequential = stack.forward(seq);
            for (bt, st) in batched[i].iter().zip(&sequential) {
                for (x, y) in bt.iter().zip(st) {
                    assert_eq!(x.to_bits(), y.to_bits(), "seed={}", g.seed);
                }
            }
        }
    });
}

/// Full server path: sessions stream pipelined tokens through the
/// micro-batching scheduler across multiple shards; every reply must be
/// bit-identical to the offline sequential forward of that session's
/// sequence — state isolation, ordering, and batching all at once.
#[test]
fn server_replies_bit_identical_to_sequential_forward() {
    let vocab = 48;
    let stack = Arc::new(synthetic_stack(vocab, 6, 10, 2, vocab, 2026));
    let server = Server::start_lm(
        stack.clone(),
        ServeConfig { workers: 3, max_batch: 4, batch_window: Duration::from_micros(100) },
    )
    .unwrap();

    let seqs = ragged_seqs(7, vocab, 0xBEEF);
    // pipeline: submit every token of every session up front — the
    // scheduler must keep per-session order and never co-batch them
    let mut rxs = Vec::new();
    for (s, seq) in seqs.iter().enumerate() {
        let (tx, rx) = mpsc::channel();
        for &tok in seq {
            server.submit(s as u64, tok, tx.clone()).unwrap();
        }
        rxs.push(rx);
    }

    for (s, seq) in seqs.iter().enumerate() {
        let expected = stack.forward(seq);
        for (t, want) in expected.iter().enumerate() {
            let reply = rxs[s]
                .recv_timeout(Duration::from_secs(10))
                .unwrap_or_else(|e| panic!("session {s} token {t}: no reply ({e})"));
            assert_eq!(reply.session, s as u64);
            let logits = reply.logits().expect("step reply carries logits");
            assert_bits_eq(logits, want, &format!("server logits (s={s} t={t})"));
        }
    }

    let agg = server.stats();
    let total: usize = seqs.iter().map(|s| s.len()).sum();
    assert_eq!(agg.tokens, total as u64, "every submitted token served exactly once");
    server.shutdown();
}

/// Per-session FIFO under contention: one hot session pipelines a long
/// token stream up front while noisy sessions keep every micro-batch
/// full, on a single shard with a tiny `max_batch` — so the scheduler
/// constantly defers the hot session's surplus tokens (and exercises
/// the scan-budget path). Every hot-session reply must arrive in
/// submission order with logits bit-identical to the unbatched replay
/// of that exact sequence.
#[test]
fn scheduler_keeps_per_session_fifo_under_contention() {
    let vocab = 32;
    let stack = Arc::new(synthetic_stack(vocab, 5, 9, 2, vocab, 404));
    let server = Server::start_lm(
        stack.clone(),
        // one worker: every session contends for the same queue
        ServeConfig { workers: 1, max_batch: 3, batch_window: Duration::from_micros(50) },
    )
    .unwrap();

    let mut rng = SplitMix64::new(0xF1F0);
    let hot: Vec<usize> = (0..120).map(|_| rng.next_below(vocab as u64) as usize).collect();
    let noisy: Vec<Vec<usize>> =
        (0..5).map(|_| (0..40).map(|_| rng.next_below(vocab as u64) as usize).collect()).collect();

    // hot session 0 pipelines everything up front...
    let (hot_tx, hot_rx) = mpsc::channel();
    for &tok in &hot {
        server.submit(0, tok, hot_tx.clone()).unwrap();
    }
    // ...then the noisy sessions pile on behind it
    let (noise_tx, noise_rx) = mpsc::channel();
    for (i, seq) in noisy.iter().enumerate() {
        for &tok in seq {
            server.submit(1 + i as u64, tok, noise_tx.clone()).unwrap();
        }
    }

    let expected = stack.forward(&hot);
    for (t, want) in expected.iter().enumerate() {
        let reply = hot_rx
            .recv_timeout(Duration::from_secs(10))
            .unwrap_or_else(|e| panic!("hot session token {t}: no reply ({e})"));
        assert_eq!(reply.session, 0);
        let logits = reply.logits().expect("step reply carries logits");
        // any reordering (or state mixup with a noisy session) breaks
        // the recurrent state and flips bits from this token onward
        assert_bits_eq(logits, want, &format!("hot-session logits under contention (t={t})"));
    }
    // the noisy sessions were all served too, in their own order
    let mut noise_replies = 0usize;
    let noise_total: usize = noisy.iter().map(|s| s.len()).sum();
    while noise_replies < noise_total {
        let reply = noise_rx.recv_timeout(Duration::from_secs(10)).expect("noisy reply");
        assert!(!reply.is_rejected());
        noise_replies += 1;
    }
    let agg = server.stats();
    assert_eq!(agg.tokens, (hot.len() + noise_total) as u64);
    server.shutdown();
}

/// Serving rejects models that cannot stream.
#[test]
fn forward_batch_rejects_bidirectional() {
    let stack = synthetic_stack(16, 4, 6, 1, 16, 9);
    let mut bidi = synthetic_stack(16, 4, 6, 1, 16, 9);
    bidi.layers[0].bwd = Some(rand_cell(4, 6, 1));
    let seq = [1usize, 2, 3];
    let refs: Vec<&[usize]> = vec![&seq[..]];
    let _ok = stack.forward_batch(&refs); // unidirectional fine
    let r = std::panic::catch_unwind(|| bidi.forward_batch(&refs));
    assert!(r.is_err(), "bidirectional stack must refuse token-at-a-time batching");
}

/// weight_bytes sanity on the serving model (keeps the paper's 4x
/// footprint claim wired through the new multi-layer builder).
#[test]
fn synthetic_stack_weight_footprint_ratio() {
    let stack: QLstmStack = synthetic_stack(64, 16, 24, 3, 64, 4);
    let (sd8, fp32) = stack.weight_bytes();
    assert_eq!(fp32, 4 * sd8);
    assert_eq!(stack.hidden_dims(), vec![24, 24, 24]);
    assert!(stack.is_unidirectional());
}
