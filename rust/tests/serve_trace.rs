//! The serve-trace determinism contract, pinned end to end: attaching
//! a [`ServeTraceSink`] to a server must never perturb a served
//! logit, decode token, or stats counter — for any of the four task
//! heads. The `floatsd-serve-trace-v1` stream itself is validated
//! record kind by record kind, and a fixed sequential schedule on one
//! worker reproduces the stream byte-identically once the clearly
//! marked `"timing"` fields (and the wall-clock kernel profile) are
//! stripped. The eval-side counterpart: `build_report` emits the same
//! report bytes with and without a `--trace` sink attached.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use floatsd_lstm::lstm::synthetic_stack;
use floatsd_lstm::serve::{DecodeParams, Payload, ServeConfig, ServeModel, Server};
use floatsd_lstm::tasks::TaskKind;
use floatsd_lstm::telemetry::{ServeTraceSink, TraceSink, SERVE_TRACE_SCHEMA, TRACE_SCHEMA};
use floatsd_lstm::tensorfile::json::Json;

const RECV: Duration = Duration::from_secs(30);

fn test_dir() -> PathBuf {
    let dir = std::env::temp_dir().join("fsd_serve_trace_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn tiny_cfg(workers: usize) -> ServeConfig {
    ServeConfig { workers, max_batch: 4, batch_window: Duration::from_micros(50) }
}

/// Miniature synthetic models, one per task head (the same shapes the
/// serve demo tests use — no checkpoint needed).
fn model_for(kind: TaskKind) -> Arc<ServeModel> {
    let m = match kind {
        TaskKind::Lm => ServeModel::lm(Arc::new(synthetic_stack(32, 8, 12, 1, 32, 41))),
        TaskKind::Pos => ServeModel::from_parts(
            TaskKind::Pos,
            Arc::new(synthetic_stack(60, 8, 10, 1, 6, 42)),
            None,
            None,
        ),
        TaskKind::Nli => ServeModel::from_parts(
            TaskKind::Nli,
            Arc::new(synthetic_stack(24, 8, 10, 1, 3, 43)),
            None,
            None,
        ),
        TaskKind::Mt => ServeModel::from_parts(
            TaskKind::Mt,
            Arc::new(synthetic_stack(20, 6, 12, 1, 1, 44)),
            Some(Arc::new(synthetic_stack(20, 6, 12, 1, 20, 45))),
            None,
        ),
    };
    Arc::new(m.expect("synthetic serve model"))
}

fn push_logits(bits: &mut Vec<u64>, lg: &[f32]) {
    bits.extend(lg.iter().map(|v| v.to_bits() as u64));
}

/// Drive a fixed, fully sequential load (one request in flight at a
/// time, every reply received before the next submit) and fold every
/// numeric output — logits, argmaxes, decode tokens, scores — into
/// one bit vector. Sequential driving makes the realized schedule,
/// and therefore every non-timing trace field, deterministic.
fn drive(model: &ServeModel, server: &Server) -> Vec<u64> {
    let vocab = model.input_vocab();
    let mut bits = Vec::new();
    let (tx, rx) = mpsc::channel();
    let recv = || rx.recv_timeout(RECV).expect("serve reply");
    match model.task {
        TaskKind::Lm => {
            for s in 0..3u64 {
                for t in 0..6usize {
                    server.submit(s, (s as usize * 7 + t * 3) % vocab, tx.clone()).unwrap();
                    let r = recv();
                    push_logits(&mut bits, r.logits().expect("step logits"));
                    bits.push(r.top_token().unwrap() as u64);
                }
            }
        }
        TaskKind::Pos => {
            for s in 0..3u64 {
                let toks: Vec<usize> =
                    (0..5).map(|t| (s as usize * 11 + t * 5) % vocab).collect();
                server.submit_sequence(s, toks, tx.clone()).unwrap();
                match recv().payload {
                    Payload::Steps { logits } => {
                        for row in &logits {
                            push_logits(&mut bits, row);
                        }
                    }
                    _ => panic!("pos sequence reply must carry per-step tag scores"),
                }
            }
        }
        TaskKind::Nli => {
            for s in 0..3u64 {
                let toks: Vec<usize> =
                    (0..6).map(|t| (s as usize * 5 + t * 3) % vocab).collect();
                server.submit_sequence(s, toks, tx.clone()).unwrap();
                let r = recv();
                push_logits(&mut bits, r.logits().expect("prefill logits"));
                server.finalize(s, tx.clone()).unwrap();
                match recv().payload {
                    Payload::Class { logits, label } => {
                        push_logits(&mut bits, &logits);
                        bits.push(label as u64);
                    }
                    _ => panic!("nli finalize reply must carry a classification"),
                }
            }
        }
        TaskKind::Mt => {
            for s in 0..2u64 {
                let toks: Vec<usize> =
                    (0..4).map(|t| (s as usize * 3 + t * 5 + 1) % vocab).collect();
                server.submit_sequence(s, toks, tx.clone()).unwrap();
                match recv().payload {
                    Payload::Encoded { consumed } => bits.push(consumed as u64),
                    _ => panic!("mt sequence reply must be an encoder ack"),
                }
                for (beam, alpha) in [(1usize, 0.0f32), (3, 0.5)] {
                    let p = DecodeParams { max_len: 8, beam_width: beam, len_norm: alpha };
                    server.decode(s, p, tx.clone()).unwrap();
                    match recv().payload {
                        Payload::Decoded { tokens, score } => {
                            bits.extend(tokens.iter().map(|&t| t as u64));
                            bits.push(score.to_bits() as u64);
                        }
                        _ => panic!("mt decode reply must carry tokens"),
                    }
                }
            }
        }
    }
    bits
}

#[test]
fn tracing_never_perturbs_served_replies_for_any_task_head() {
    let dir = test_dir();
    for kind in TaskKind::ALL {
        let model = model_for(kind);
        let server = Server::start(model.clone(), tiny_cfg(2)).unwrap();
        let base = drive(&model, &server);
        let off = server.stats();
        server.shutdown();
        assert!(!base.is_empty(), "{}: load produced no outputs", kind.name());

        let trace = dir.join(format!("parity_{}.jsonl", kind.name()));
        let sink = Arc::new(ServeTraceSink::create(&trace).unwrap());
        let server =
            Server::start_traced(model.clone(), tiny_cfg(2), Some(sink.clone())).unwrap();
        let traced = drive(&model, &server);
        let on = server.stats();
        server.shutdown();
        sink.finish().unwrap();
        drop(sink);

        assert_eq!(traced, base, "{}: served bits diverged with --trace", kind.name());
        // sequential driving realizes the same schedule both times, so
        // the stats counters must match exactly — tracing can't even
        // shift a batch boundary here
        let name = kind.name();
        assert_eq!(on.tokens, off.tokens, "{name}: token counter drifted under --trace");
        assert_eq!(on.requests, off.requests, "{name}: request counter drifted");
        assert_eq!(on.batches, off.batches, "{name}: batch counter drifted");
        assert_eq!(on.sessions, off.sessions, "{name}: session gauge drifted");
        assert_eq!(on.queue_high_water, off.queue_high_water, "{name}: high-water drifted");

        let text = std::fs::read_to_string(&trace).unwrap();
        let evs: Vec<String> = text
            .lines()
            .map(|l| {
                let j = Json::parse(l).expect("trace line parses");
                j.get("ev").and_then(Json::as_str).unwrap_or("?").to_string()
            })
            .collect();
        assert_eq!(evs.first().map(String::as_str), Some("serve_start"), "{name}");
        assert_eq!(evs.last().map(String::as_str), Some("serve_end"), "{name}");
        assert!(evs.iter().any(|e| e == "request"), "{name}: no request spans: {evs:?}");
    }
}

/// Assert `j` has key `k`; failure names the event kind and the line.
fn want_key(j: &Json, ev: &str, k: &str) {
    assert!(j.get(k).is_some(), "{ev} record missing {k:?}: {j}");
}

#[test]
fn serve_trace_stream_covers_every_record_kind_with_valid_fields() {
    let dir = test_dir();
    let trace = dir.join("schema.jsonl");
    let model = model_for(TaskKind::Lm);
    let vocab = model.input_vocab();
    let sink = Arc::new(ServeTraceSink::create(&trace).unwrap());
    let server = Server::start_traced(model, tiny_cfg(1), Some(sink.clone())).unwrap();
    let (tx, rx) = mpsc::channel();
    for s in 0..2u64 {
        for t in 0..3usize {
            server.submit(s, (s as usize + t * 5) % vocab, tx.clone()).unwrap();
            assert!(!rx.recv_timeout(RECV).unwrap().is_rejected());
        }
    }
    // an out-of-vocab token bounces at the front door — and traces
    assert!(server.submit(0, vocab, tx.clone()).is_err());
    // a close drains at the next batch boundary; the follow-up submit
    // guarantees that boundary happens before shutdown
    server.close_session(0);
    server.submit(1, 1, tx.clone()).unwrap();
    rx.recv_timeout(RECV).unwrap();
    server.shutdown();
    sink.finish().unwrap();
    drop(sink);

    let text = std::fs::read_to_string(&trace).unwrap();
    let mut kinds: BTreeSet<String> = BTreeSet::new();
    let mut lines: Vec<(String, Json)> = Vec::new();
    for line in text.lines() {
        let j = Json::parse(line).expect("every serve-trace line parses as JSON");
        assert_eq!(
            j.get("schema").and_then(Json::as_str),
            Some(SERVE_TRACE_SCHEMA),
            "line missing the schema tag: {line}"
        );
        let ev = j.get("ev").and_then(Json::as_str).expect("every line carries ev").to_string();
        kinds.insert(ev.clone());
        lines.push((ev, j));
    }
    for want in ["serve_start", "session_open", "session_close", "reject"] {
        assert!(kinds.contains(want), "stream never emitted {want:?}: {kinds:?}");
    }
    for want in ["batch", "request", "serve_end"] {
        assert!(kinds.contains(want), "stream never emitted {want:?}: {kinds:?}");
    }
    assert_eq!(lines.first().map(|(e, _)| e.as_str()), Some("serve_start"));
    assert_eq!(lines.last().map(|(e, _)| e.as_str()), Some("serve_end"));

    for (ev, j) in &lines {
        match ev.as_str() {
            "serve_start" => {
                for k in ["task", "workers", "max_batch", "window_us", "kernel_tier"] {
                    want_key(j, ev, k);
                }
                for k in ["vocab", "n_out"] {
                    want_key(j, ev, k);
                }
                assert_eq!(j.get("task").and_then(Json::as_str), Some("lm"));
                assert_eq!(j.get("workers").and_then(Json::as_usize), Some(1));
            }
            "session_open" => {
                want_key(j, ev, "shard");
                want_key(j, ev, "session");
            }
            "session_close" => {
                want_key(j, ev, "shard");
                want_key(j, ev, "session");
                assert!(j.get("existed").and_then(Json::as_bool).is_some(), "{j}");
            }
            "reject" => {
                for k in ["shard", "session", "kind", "reason"] {
                    want_key(j, ev, k);
                }
                assert_eq!(j.get("kind").and_then(Json::as_str), Some("step"));
            }
            "batch" => {
                for k in ["shard", "batch", "requests", "work", "closes", "kinds"] {
                    want_key(j, ev, k);
                }
                for k in ["queue_depth", "queue_high_water", "sessions"] {
                    want_key(j, ev, k);
                }
                let t = j.get("timing").expect("batch carries a timing block");
                assert!(t.get("batch_ms").and_then(Json::as_f64).is_some(), "{j}");
            }
            "request" => {
                for k in ["shard", "batch", "session", "kind", "work", "occupancy"] {
                    want_key(j, ev, k);
                }
                let t = j.get("timing").expect("request carries a timing block");
                assert!(t.get("queue_wait_us").and_then(Json::as_f64).is_some(), "{j}");
                assert!(t.get("service_us").and_then(Json::as_f64).is_some(), "{j}");
            }
            "serve_end" => {
                for k in ["tokens", "requests", "batches", "sessions", "queue_high_water"] {
                    want_key(j, ev, k);
                }
                for k in ["kernel_tier", "kernel_profile"] {
                    want_key(j, ev, k);
                }
                let t = j.get("timing").expect("serve_end carries a timing block");
                assert!(t.get("p50_us").and_then(Json::as_f64).is_some(), "{j}");
                assert!(t.get("p99_us").and_then(Json::as_f64).is_some(), "{j}");
                let prof = j.get("kernel_profile").and_then(Json::as_arr).expect("profile");
                assert!(!prof.is_empty(), "kernel profile empty after a served load");
                for row in prof {
                    for k in ["op", "tier", "rows", "cols", "batch", "calls"] {
                        want_key(row, "kernel_profile row", k);
                    }
                    assert!(row.get("calls").and_then(Json::as_usize).unwrap_or(0) > 0, "{row}");
                    let rt = row.get("timing").expect("profile wall time sits under timing");
                    assert!(rt.get("total_ms").and_then(Json::as_f64).is_some(), "{row}");
                }
            }
            other => panic!("unknown serve-trace event kind {other:?}"),
        }
    }
}

/// Recursively drop every `"timing"` block — the only fields the
/// schema allows wall clock into — at any nesting depth (the kernel
/// profile nests one per shape-class row).
fn strip_timing(j: &mut Json) {
    match j {
        Json::Obj(m) => {
            m.remove("timing");
            for v in m.values_mut() {
                strip_timing(v);
            }
        }
        Json::Arr(items) => {
            for v in items.iter_mut() {
                strip_timing(v);
            }
        }
        _ => {}
    }
}

/// Parse a serve trace into its deterministic residue: `"timing"`
/// stripped recursively, plus the `kernel_profile` block (its
/// shape-class rows come from a process-wide table the other tests in
/// this binary also feed while any sink holds the gate open, so its
/// row set is not per-run deterministic under the parallel harness).
fn deterministic_serve_lines(path: &Path) -> Vec<String> {
    let text = std::fs::read_to_string(path).expect("read serve trace");
    text.lines()
        .map(|line| {
            let mut j = Json::parse(line).expect("serve-trace line parses");
            strip_timing(&mut j);
            if let Json::Obj(m) = &mut j {
                m.remove("kernel_profile");
            }
            j.to_string()
        })
        .collect()
}

#[test]
fn serve_trace_is_byte_deterministic_for_a_fixed_sequential_schedule() {
    let dir = test_dir();
    let run = |n: usize| -> PathBuf {
        let trace = dir.join(format!("det_{n}.jsonl"));
        let model = model_for(TaskKind::Nli);
        let sink = Arc::new(ServeTraceSink::create(&trace).unwrap());
        let server =
            Server::start_traced(model.clone(), tiny_cfg(1), Some(sink.clone())).unwrap();
        drive(&model, &server);
        // exercise the close path, flushed through a live batch so
        // both runs drain it at the same boundary
        server.close_session(0);
        let (tx, rx) = mpsc::channel();
        server.submit_sequence(1, vec![1, 2], tx).unwrap();
        rx.recv_timeout(RECV).unwrap();
        server.shutdown();
        sink.finish().unwrap();
        trace
    };
    let l1 = deterministic_serve_lines(&run(1));
    let l2 = deterministic_serve_lines(&run(2));
    assert_eq!(l1, l2, "fixed-schedule serve traces diverged beyond timing fields");
    // the residue still covers the full lifecycle, not a trivial stream
    let evs: BTreeSet<String> = l1
        .iter()
        .map(|l| {
            let j = Json::parse(l).unwrap();
            j.get("ev").and_then(Json::as_str).unwrap_or("?").to_string()
        })
        .collect();
    for want in ["serve_start", "session_open", "session_close", "batch"] {
        assert!(evs.contains(want), "deterministic residue lost {want:?}: {evs:?}");
    }
    for want in ["request", "serve_end"] {
        assert!(evs.contains(want), "deterministic residue lost {want:?}: {evs:?}");
    }
}

/// Order-preserving two-pointer subsequence check.
fn is_subsequence(sub: &[String], full: &[String]) -> bool {
    let mut it = full.iter();
    sub.iter().all(|line| it.any(|f| f == line))
}

#[test]
fn trace_every_samples_batch_lines_without_perturbing_served_bits() {
    let dir = test_dir();
    let model = model_for(TaskKind::Lm);
    // sequential driving on one worker realizes the same schedule (and
    // the same per-shard batch ordinals) in both runs
    let run = |every: u64, name: &str| -> (Vec<u64>, PathBuf) {
        let trace = dir.join(format!("sampled_{name}.jsonl"));
        let sink = Arc::new(ServeTraceSink::create_every(&trace, every).unwrap());
        let server =
            Server::start_traced(model.clone(), tiny_cfg(1), Some(sink.clone())).unwrap();
        let bits = drive(&model, &server);
        // exercise the (never-sampled) close path through a live batch
        server.close_session(0);
        let (tx, rx) = mpsc::channel();
        server.submit(1, 1, tx).unwrap();
        rx.recv_timeout(RECV).unwrap();
        server.shutdown();
        sink.finish().unwrap();
        (bits, trace)
    };
    let (bits_full, full_path) = run(1, "full");
    let (bits_sampled, sampled_path) = run(3, "every3");
    assert_eq!(bits_sampled, bits_full, "--trace-every perturbed served bits");

    // serve_start records the period (and is the only line that may
    // differ between the runs — drop it from the residue compare)
    let first_ev = |p: &Path| -> Json {
        let text = std::fs::read_to_string(p).unwrap();
        Json::parse(text.lines().next().expect("non-empty trace")).unwrap()
    };
    assert_eq!(first_ev(&full_path).get("trace_every").and_then(Json::as_usize), Some(1));
    assert_eq!(first_ev(&sampled_path).get("trace_every").and_then(Json::as_usize), Some(3));

    let residue = |p: &Path| -> Vec<String> {
        deterministic_serve_lines(p)
            .into_iter()
            .map(|l| {
                let mut j = Json::parse(&l).unwrap();
                if let Json::Obj(m) = &mut j {
                    m.remove("trace_every");
                }
                j.to_string()
            })
            .collect()
    };
    let full = residue(&full_path);
    let sampled = residue(&sampled_path);
    assert!(
        is_subsequence(&sampled, &full),
        "sampled stream must be a strict subsequence of the full stream"
    );

    let count = |lines: &[String], ev: &str| {
        lines
            .iter()
            .filter(|l| Json::parse(l).unwrap().get("ev").and_then(Json::as_str) == Some(ev))
            .count()
    };
    // lifecycle events and the summary are never sampled away
    for want in ["serve_start", "session_open", "session_close", "serve_end"] {
        assert_eq!(count(&sampled, want), count(&full, want), "sampling touched {want:?}");
        assert!(count(&sampled, want) > 0, "stream never emitted {want:?}");
    }
    // batch-level lines are thinned...
    let full_batches = count(&full, "batch");
    let sampled_batches = count(&sampled, "batch");
    assert!(full_batches >= 3, "load too small to exercise sampling: {full_batches} batches");
    assert!(
        sampled_batches < full_batches && sampled_batches > 0,
        "every=3 kept {sampled_batches} of {full_batches} batch lines"
    );
    assert!(count(&sampled, "request") < count(&full, "request"), "request lines not thinned");
    // ...and the kept ones are exactly the N-th, 2N-th, ... per shard
    for l in &sampled {
        let j = Json::parse(l).unwrap();
        if j.get("ev").and_then(Json::as_str) == Some("batch") {
            let b = j.get("batch").and_then(Json::as_usize).unwrap() as u64;
            assert_eq!((b + 1) % 3, 0, "batch ordinal {b} should have been sampled away");
        }
    }
}

#[test]
fn eval_report_bytes_are_identical_with_and_without_a_trace_sink() {
    use floatsd_lstm::qmath::{IsaPath, KernelTier};
    use floatsd_lstm::tasks::eval::{build_report_tier, build_report_traced};

    let dir = test_dir();
    let plain = build_report_tier(&[], 2, KernelTier::Decoded).unwrap().to_string();
    let trace = dir.join("eval_spans.jsonl");
    let mut sink = TraceSink::create(&trace).unwrap();
    let traced =
        build_report_traced(&[], 2, KernelTier::Decoded, IsaPath::detect(), Some(&mut sink))
            .unwrap()
            .to_string();
    sink.finish().unwrap();
    drop(sink);
    assert_eq!(traced, plain, "eval report bytes changed with a trace sink attached");

    // the sink carries the per-shard span timings the report never
    // includes: every line an eval_span on the train-trace schema,
    // wall clock confined to its timing block, all four tasks covered
    let text = std::fs::read_to_string(&trace).unwrap();
    let mut tasks: BTreeSet<String> = BTreeSet::new();
    let mut n = 0usize;
    for line in text.lines() {
        let j = Json::parse(line).expect("eval trace line parses");
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(TRACE_SCHEMA));
        assert_eq!(j.get("ev").and_then(Json::as_str), Some("eval_span"));
        for k in ["task", "lo", "hi", "count"] {
            want_key(&j, "eval_span", k);
        }
        let t = j.get("timing").expect("span wall time sits under timing");
        assert!(t.get("ms").and_then(Json::as_f64).is_some(), "{j}");
        tasks.insert(j.get("task").and_then(Json::as_str).unwrap().to_string());
        n += 1;
    }
    assert!(n > 0, "eval --trace emitted no spans");
    let all: BTreeSet<String> =
        TaskKind::ALL.iter().map(|k| k.name().to_string()).collect();
    assert_eq!(tasks, all, "eval spans must cover every task in the grid");
}
