//! Generator-contract tests for the synthetic task datasets
//! (`data::{pos, nli, translation}`): determinism in the seed, batch
//! shape conformance, and label validity (class ranges, PAD
//! placement). These are the invariants the task heads (`tasks/`)
//! lean on — a generator drifting out of contract would show up as
//! training mysteriously failing, so it gets pinned here instead.

use floatsd_lstm::data::nli::{NEG, PAD};
use floatsd_lstm::data::translation::{BOS, EOS, PAD as MT_PAD};
use floatsd_lstm::data::{make_source, Batch, BatchSource};

/// (task, x_shape, y_shape, vocab, vocab_tgt, n_classes)
type Spec = (&'static str, Vec<usize>, Vec<usize>, usize, usize, usize);

fn specs() -> Vec<Spec> {
    vec![
        ("pos", vec![12], vec![12], 96, 0, 8),
        ("nli", vec![2, 10], vec![], 64, 0, 3),
        ("mt", vec![9], vec![11], 48, 48, 0),
    ]
}

fn source(spec: &Spec, batch: usize, eval_batches: usize, seed: u64) -> Box<dyn BatchSource> {
    make_source(spec.0, batch, &spec.1, &spec.2, spec.3, spec.4, spec.5, eval_batches, seed)
        .expect("valid spec")
}

fn batches_equal(a: &Batch, b: &Batch) -> bool {
    a.x == b.x && a.y == b.y && a.x_shape == b.x_shape && a.y_shape == b.y_shape
}

#[test]
fn generators_are_deterministic_in_seed() {
    for spec in specs() {
        let (mut a, mut b) = (source(&spec, 6, 3, 42), source(&spec, 6, 3, 42));
        for w in 0..6 {
            let (ba, bb) = (a.next_train(), b.next_train());
            assert!(batches_equal(&ba, &bb), "{}: window {w} diverged for equal seeds", spec.0);
        }
        for (ea, eb) in a.eval_set().iter().zip(b.eval_set()) {
            assert!(batches_equal(ea, eb), "{}: eval sets diverged for equal seeds", spec.0);
        }
        // and a different seed must actually change the stream
        let mut c = source(&spec, 6, 3, 43);
        let (ba, bc) = (source(&spec, 6, 3, 42).next_train(), c.next_train());
        assert_ne!(ba.x, bc.x, "{}: seed is inert", spec.0);
    }
}

#[test]
fn batch_shapes_conform_to_declared_shapes() {
    for spec in specs() {
        let batch = 5usize;
        let mut src = source(&spec, batch, 2, 7);
        for b in [src.next_train(), src.next_train()] {
            let x_want: usize = b.x_shape.iter().product();
            let y_want: usize = b.y_shape.iter().product::<usize>().max(1);
            assert_eq!(b.x.len(), x_want, "{}: x vs x_shape {:?}", spec.0, b.x_shape);
            assert_eq!(b.y.len(), y_want, "{}: y vs y_shape {:?}", spec.0, b.y_shape);
            // leading dim is the batch; the rest is the per-example spec
            assert_eq!(b.x_shape[0], batch, "{}: x batch dim", spec.0);
            assert_eq!(&b.x_shape[1..], &spec.1[..], "{}: per-example x shape", spec.0);
            if spec.2.is_empty() {
                assert_eq!(b.y_shape, vec![batch], "{}: scalar labels", spec.0);
            } else {
                assert_eq!(b.y_shape[0], batch, "{}: y batch dim", spec.0);
                assert_eq!(&b.y_shape[1..], &spec.2[..], "{}: per-example y shape", spec.0);
            }
        }
        assert_eq!(src.eval_set().len(), 2, "{}: eval batches", spec.0);
    }
}

#[test]
fn pos_labels_are_valid_tags_and_words_in_vocab() {
    let (vocab, n_tags) = (96usize, 8usize);
    let mut src = make_source("pos", 8, &[12], &[12], vocab, 0, n_tags, 2, 3).unwrap();
    let mut seen_tags = vec![false; n_tags];
    for _ in 0..20 {
        let b = src.next_train();
        for (&w, &t) in b.x.iter().zip(&b.y) {
            assert!((0..vocab as i32).contains(&w), "word {w} out of vocab");
            assert!((0..n_tags as i32).contains(&t), "tag {t} out of range");
            seen_tags[t as usize] = true;
        }
    }
    assert!(seen_tags.iter().all(|&s| s), "some tag class never sampled");
}

#[test]
fn nli_labels_in_class_range_and_pad_only_in_hypothesis() {
    let (vocab, seq, batch) = (64usize, 10usize, 8usize);
    let mut src = make_source("nli", batch, &[2, seq], &[], vocab, 0, 3, 2, 5).unwrap();
    let mut seen = [false; 3];
    for _ in 0..20 {
        let b = src.next_train();
        assert_eq!(b.y.len(), batch);
        for &label in &b.y {
            assert!((0..3).contains(&label), "label {label} out of 3-way range");
            seen[label as usize] = true;
        }
        for lane in 0..batch {
            let row = &b.x[lane * 2 * seq..(lane + 1) * 2 * seq];
            let (premise, hyp) = row.split_at(seq);
            // premise is pure content: no PAD, no NEG
            for &w in premise {
                assert!(w != PAD && w != NEG, "reserved token {w} in premise");
                assert!((0..vocab as i32).contains(&w));
            }
            // hypothesis may pad its tail / splice NEG, but stays in vocab
            for &w in hyp {
                assert!((0..vocab as i32).contains(&w), "hyp token {w} out of vocab");
            }
        }
    }
    assert!(seen.iter().all(|&s| s), "some NLI class never sampled");
}

#[test]
fn mt_targets_are_bos_prefixed_eos_terminated_and_in_target_vocab() {
    let (v_src, v_tgt, s_len, batch) = (48usize, 48usize, 9usize, 6usize);
    let t_len = s_len + 2;
    let mut src = make_source("mt", batch, &[s_len], &[t_len], v_src, v_tgt, 0, 2, 9).unwrap();
    for _ in 0..10 {
        let b = src.next_train();
        for lane in 0..batch {
            let tgt = &b.y[lane * t_len..(lane + 1) * t_len];
            assert_eq!(tgt[0], BOS, "target must open with BOS");
            assert_eq!(tgt[t_len - 1], EOS, "target must close with EOS");
            for &w in &tgt[1..t_len - 1] {
                assert!((0..v_tgt as i32).contains(&w), "target token {w} out of vocab");
                assert_ne!(w, MT_PAD, "generator never emits PAD content");
                assert_ne!(w, BOS, "BOS only at position 0");
                assert_ne!(w, EOS, "EOS only at the final position");
            }
            let src_row = &b.x[lane * s_len..(lane + 1) * s_len];
            for &w in src_row {
                assert!((3..v_src as i32).contains(&w), "source token {w} outside content ids");
            }
        }
    }
    // old +1-shaped targets must be refused with the new contract
    let err = make_source("mt", batch, &[s_len], &[s_len + 1], v_src, v_tgt, 0, 1, 9)
        .unwrap_err()
        .to_string();
    assert!(err.contains("+ 2"), "got: {err}");
}
