//! Property tests (proptest-lite) over the numeric formats and the
//! quantized math — invariants the whole stack relies on.

use floatsd_lstm::formats::{round_f16, round_f8, round_sd8, FloatSd8, Fp16, Fp8, FLOAT_SD8};
use floatsd_lstm::qmath::mac::{mac_exact, MAC_GROUP};
use floatsd_lstm::qmath::qsigmoid::sigmoid_sd8;
use floatsd_lstm::qmath::shiftadd::WeightDigits;
use floatsd_lstm::qmath::vector::{matvec_fast, QMatrix};
use floatsd_lstm::qmath::KernelTier;
use floatsd_lstm::testing::{property, Gen};

#[test]
fn quantizers_are_idempotent() {
    property("idempotence", 3000, |g: &mut Gen| {
        let x = g.f32_log(-30, 20);
        for (name, q) in [("sd8", round_sd8 as fn(f32) -> f32), ("fp8", round_f8), ("fp16", round_f16)] {
            let once = q(x);
            assert_eq!(q(once).to_bits(), once.to_bits(), "{name}({x})");
        }
    });
}

#[test]
fn quantizers_are_monotone() {
    property("monotonicity", 3000, |g: &mut Gen| {
        let a = g.f32_range(-10.0, 10.0);
        let b = g.f32_range(-10.0, 10.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(round_sd8(lo) <= round_sd8(hi), "sd8 order at {lo},{hi}");
        assert!(round_f8(lo) <= round_f8(hi), "fp8 order at {lo},{hi}");
        assert!(round_f16(lo) <= round_f16(hi), "fp16 order at {lo},{hi}");
    });
}

#[test]
fn quantizers_are_odd_functions() {
    property("symmetry", 3000, |g: &mut Gen| {
        let x = g.f32_log(-20, 18);
        assert_eq!(round_sd8(-x), -round_sd8(x));
        assert_eq!(round_f8(-x), -round_f8(x));
    });
}

#[test]
fn sd8_encode_decode_identity_on_grid() {
    property("encode∘decode", 2000, |g: &mut Gen| {
        let x = g.f32_range(-5.0, 5.0);
        let q = round_sd8(x);
        let code = FLOAT_SD8.encode(q);
        assert_eq!(FLOAT_SD8.decode(code), q);
    });
}

#[test]
fn sd8_error_bounded_by_local_gap() {
    property("nearest", 2000, |g: &mut Gen| {
        let x = g.f32_range(-4.5, 4.5);
        let q = round_sd8(x);
        let vals = FLOAT_SD8.values();
        let best = vals.iter().map(|v| (x - v).abs()).fold(f32::INFINITY, f32::min);
        assert!((x - q).abs() <= best + best * 1e-6, "x={x} q={q} best={best}");
    });
}

#[test]
fn sigmoid_quantized_complementarity() {
    property("Eq7/8 complement", 2000, |g: &mut Gen| {
        let x = g.f32_range(-12.0, 12.0);
        assert_eq!(sigmoid_sd8(x) + sigmoid_sd8(-x), 1.0, "x={x}");
    });
}

#[test]
fn mac_exact_commutes_with_pair_order() {
    property("MAC permutation invariance", 1000, |g: &mut Gen| {
        let n = 1 + g.usize_below(MAC_GROUP);
        let xs: Vec<Fp8> = (0..n).map(|_| Fp8::from_f32(g.f32_range(-64.0, 64.0))).collect();
        let ws: Vec<FloatSd8> =
            (0..n).map(|_| FLOAT_SD8.encode(g.f32_range(-4.5, 4.5))).collect();
        let acc = Fp16::from_f32(g.f32_range(-8.0, 8.0));
        let fwd = mac_exact(acc, &xs, &ws);
        let mut xr = xs.clone();
        let mut wr = ws.clone();
        xr.reverse();
        wr.reverse();
        let rev = mac_exact(acc, &xr, &wr);
        // the Wallace tree is a sum — order cannot matter
        assert_eq!(fwd.0, rev.0);
    });
}

#[test]
fn fp16_from_f64_is_correctly_rounded() {
    property("from_f64 == nearest", 4000, |g: &mut Gen| {
        let x = g.f32_log(-20, 14) as f64 * (1.0 + g.f32_range(-1e-4, 1e-4) as f64);
        let got = Fp16::from_f64(x);
        // reference: scan the two bracketing f16 codes around from_f32
        let approx = Fp16::from_f32(x as f32);
        let mut best = approx;
        let mut bestd = (best.to_f32() as f64 - x).abs();
        for delta in [-2i32, -1, 1, 2] {
            let code = (approx.0 as i32 + delta).clamp(0, u16::MAX as i32) as u16;
            let cand = Fp16::from_bits(code);
            if cand.is_nan() || cand.is_infinite() {
                continue;
            }
            if (cand.to_f32() >= 0.0) != (x >= 0.0) {
                continue;
            }
            let d = (cand.to_f32() as f64 - x).abs();
            if d < bestd {
                best = cand;
                bestd = d;
            }
        }
        let gotd = (got.to_f32() as f64 - x).abs();
        assert!(
            gotd <= bestd + f64::EPSILON,
            "x={x}: from_f64 gave {} (d={gotd}), nearest is {} (d={bestd})",
            got.to_f32(),
            best.to_f32()
        );
    });
}

#[test]
fn fp8_saturates_never_overflows() {
    property("fp8 saturation", 2000, |g: &mut Gen| {
        let x = g.f32_log(-5, 38);
        let q = round_f8(x);
        assert!(q.abs() <= 114688.0, "fp8({x}) = {q} exceeds max");
        assert!(q.is_finite());
    });
}

// ---------------------------------------------------------------------
// §III-B / §IV-C weight-update rule (FP16 master -> FloatSD8 re-encode)
// ---------------------------------------------------------------------

#[test]
fn master_update_reencodes_to_nearest_and_stays_on_fp16_grid() {
    property("update -> nearest code", 3000, |g: &mut Gen| {
        let m = round_f16(g.f32_range(-6.0, 6.0));
        let u = round_f16(g.f32_log(-24, 3)); // FP16 update, subnormals included
        let (m2, code) = FLOAT_SD8.apply_update(m, u);
        // master stays on the FP16 grid and finite
        assert!(m2.is_finite());
        assert_eq!(m2.to_bits(), round_f16(m2).to_bits(), "master off the FP16 grid");
        // the re-encoded code decodes to the quantizer's pick ...
        let w = FLOAT_SD8.decode(code);
        assert_eq!(w, FLOAT_SD8.quantize(m2), "code is not the quantization of the master");
        // ... which is a nearest codebook value (brute force over the grid)
        let best = FLOAT_SD8
            .values()
            .iter()
            .map(|v| (m2 - v).abs())
            .fold(f32::INFINITY, f32::min);
        assert!(
            (m2 - w).abs() <= best * (1.0 + 1e-6) + f32::MIN_POSITIVE,
            "m2={m2}: |m2-w|={} but nearest grid distance is {best}",
            (m2 - w).abs()
        );
    });
}

#[test]
fn master_update_code_round_trips_through_groups() {
    property("code -> groups -> code", 3000, |g: &mut Gen| {
        let m = round_f16(g.f32_range(-6.0, 6.0));
        let u = round_f16(g.f32_log(-20, 2));
        let (_, code) = FLOAT_SD8.apply_update(m, u);
        let (g0, g1) = FLOAT_SD8.to_groups(code);
        let exp = code.to_bits() >> 5;
        let back = FLOAT_SD8
            .from_groups(exp, g0, g1)
            .expect("canonical groups must be legal SD groups");
        assert_eq!(back, code, "groups ({g0},{g1}) exp {exp} did not round-trip");
    });
}

// ---------------------------------------------------------------------
// shift-add tier: digit-planar layout invariants (qmath::shiftadd)
// ---------------------------------------------------------------------

#[test]
fn digit_extraction_reconstructs_encode_exactly() {
    // exhaustive anchor: every code (canonical or not) survives
    // code -> digit-extract -> reconstruct bit-for-bit
    for bits in 0..=u8::MAX {
        let code = FloatSd8(bits);
        let d = WeightDigits::of(code);
        assert_eq!(d.value().to_bits(), FLOAT_SD8.decode(code).to_bits(), "code {bits:#04x}");
    }
    // and the property form over the encoder's actual output
    property("encode -> digits -> value", 3000, |g: &mut Gen| {
        let x = g.f32_range(-6.0, 6.0);
        let code = FLOAT_SD8.encode(x);
        let d = WeightDigits::of(code);
        assert_eq!(d.value().to_bits(), FLOAT_SD8.decode(code).to_bits(), "x={x}");
        assert!(d.count() <= 2, "more than two digits for x={x}");
        if d.count() == 2 {
            assert!(d.e0 > d.e1, "MSG digit must lead for x={x}: {d:?}");
        }
    });
}

#[test]
fn master_updates_keep_digit_planes_in_sync() {
    property("update sync", 300, |g: &mut Gen| {
        let (rows, cols) = (1 + g.usize_below(5), 1 + g.usize_below(9));
        let mut masters: Vec<f32> =
            (0..rows * cols).map(|_| round_f16(g.f32_range(-1.5, 1.5))).collect();
        let mut w = QMatrix::from_f32(rows, cols, &masters);
        // a randomized sequence of optimizer steps, including the
        // occasional large kick that forces exponent-field changes
        for _ in 0..(1 + g.usize_below(4)) {
            let deltas: Vec<f32> = (0..rows * cols)
                .map(|_| {
                    let base = g.f32_range(-0.2, 0.2);
                    if g.usize_below(8) == 0 {
                        base * 16.0
                    } else {
                        base
                    }
                })
                .collect();
            w.apply_master_update(&mut masters, &deltas);
        }
        // the cached SoA digit planes must equal a fresh extraction ...
        for r in 0..rows {
            for c in 0..cols {
                let code = w.codes[r * cols + c];
                assert_eq!(
                    w.digits().get(r, c),
                    WeightDigits::of(code),
                    "digit plane stale at ({r},{c})"
                );
            }
        }
        // ... the per-row plane views agree with the element view ...
        for r in 0..rows {
            let (s0, e0, s1, e1) = w.digit_row(r);
            assert_eq!(s0.len(), cols, "row view must exclude the padding tail");
            for c in 0..cols {
                let d = w.digits().get(r, c);
                assert_eq!((s0[c], e0[c], s1[c], e1[c]), (d.s0, d.e0, d.s1, d.e1), "({r},{c})");
            }
        }
        // ... the padded stride stays 16-aligned with an all-zero tail
        // (s == 0 means the padding can never contribute to a kernel)
        let stride = w.digits().stride();
        assert_eq!(stride % 16, 0, "stride {stride} not 16-aligned");
        assert!(stride >= cols);
        let (ps0, _, ps1, _) = w.digits().raw_planes();
        assert_eq!(ps0.len(), rows * stride);
        for r in 0..rows {
            for k in r * stride + cols..(r + 1) * stride {
                assert_eq!(ps0[k], 0, "s0 padding dirty at row {r}");
                assert_eq!(ps1[k], 0, "s1 padding dirty at row {r}");
            }
        }
        // ... and the shift-add kernel must still match decoded
        let x: Vec<f32> = (0..cols).map(|_| round_f8(g.f32_range(-4.0, 4.0))).collect();
        let bias: Vec<f32> = (0..rows).map(|_| round_f16(g.f32_range(-0.5, 0.5))).collect();
        let mut dec = vec![0f32; rows];
        let mut sa = vec![0f32; rows];
        w.set_kernel_tier(KernelTier::Decoded);
        matvec_fast(&w, &x, &bias, &mut dec);
        w.set_kernel_tier(KernelTier::ShiftAdd);
        matvec_fast(&w, &x, &bias, &mut sa);
        for r in 0..rows {
            assert_eq!(sa[r].to_bits(), dec[r].to_bits(), "post-update divergence, row {r}");
        }
    });
}

#[test]
fn sign_consistent_update_never_moves_weight_the_wrong_way() {
    property("update monotone", 3000, |g: &mut Gen| {
        let m = round_f16(g.f32_range(-5.0, 5.0));
        let u = round_f16(g.f32_log(-24, 2));
        let w_old = FLOAT_SD8.quantize(m);
        let (m2, code) = FLOAT_SD8.apply_update(m, u);
        let w_new = FLOAT_SD8.decode(code);
        if u >= 0.0 {
            assert!(m2 >= m, "positive update lowered the master: {m} + {u} -> {m2}");
            assert!(w_new >= w_old, "positive update lowered the weight: {w_old} -> {w_new}");
        } else {
            assert!(m2 <= m, "negative update raised the master: {m} + {u} -> {m2}");
            assert!(w_new <= w_old, "negative update raised the weight: {w_old} -> {w_new}");
        }
    });
}
