//! Checkpoint → serve parity, pinned bit-for-bit for all four task
//! heads: a checkpoint written by `floatsd-lstm train --task {...}`
//! must load into the serving engine (task auto-detected from
//! `meta/task_cfg`) and produce outputs **bit-identical** to the
//! offline `floatsd-lstm eval` path on the same inputs — the serving
//! engine's accuracy contract. Covers:
//!
//! * lm  — streamed per-token logits replay the eval CE (and thus the
//!         reported perplexity) exactly;
//! * pos — whole-sentence `Sequence` requests return per-step tag
//!         scores that replay eval loss and tag accuracy exactly;
//! * nli — submit-sequence-then-finalize classification replays eval
//!         loss and accuracy exactly;
//! * mt  — the greedy decode loop (batched across sessions) matches
//!         the offline single-lane reference token-for-token and
//!         score-bit-for-score-bit; beam_width=1 reproduces greedy;
//!         wider beams are deterministic.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use floatsd_lstm::data::lm::LmGen;
use floatsd_lstm::data::nli::NliGen;
use floatsd_lstm::data::pos::PosGen;
use floatsd_lstm::data::translation::MtGen;
use floatsd_lstm::data::BatchSource;
use floatsd_lstm::serve::{DecodeParams, Payload, Reply, ServeConfig, ServeModel, Server};
use floatsd_lstm::tasks::eval::evaluate_checkpoint;
use floatsd_lstm::tasks::{TaskConfig, TaskKind, TaskTrainer};
use floatsd_lstm::train::{eval_ce, lane_spans};

const RECV: Duration = Duration::from_secs(30);

fn serve_cfg(workers: usize) -> ServeConfig {
    ServeConfig { workers, max_batch: 4, batch_window: Duration::from_micros(100) }
}

/// First-max argmax — the same tie-break the eval harness uses.
fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Train a tiny head offline for a few steps and checkpoint it — the
/// same path the CI smoke job drives through the CLI.
fn train_ckpt(mut cfg: TaskConfig, name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("fsd_serve_tasks");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    cfg.checkpoint = Some(path.clone());
    cfg.log_every = 0;
    let mut trainer = TaskTrainer::new(cfg).expect("task config valid");
    trainer.train().expect("tiny training run");
    path
}

#[test]
fn lm_checkpoint_streams_bit_identical_to_eval() {
    let mut cfg = TaskConfig::preset(TaskKind::Lm);
    cfg.vocab = 32;
    cfg.dim = 8;
    cfg.hidden = 10;
    cfg.batch = 4;
    cfg.seq = 8;
    cfg.eval_batches = 2;
    cfg.steps = 6;
    cfg.seed = 5;
    let ckpt = train_ckpt(cfg, "lm_parity.tensors");
    let (cfg, want) = evaluate_checkpoint(&ckpt, 1).expect("offline eval");

    let model = Arc::new(ServeModel::load(&ckpt).expect("serve auto-detects lm"));
    assert_eq!(model.task, TaskKind::Lm);
    let server = Server::start(model, serve_cfg(2)).unwrap();

    // the eval lanes are contiguous held-out streams whose state
    // carries across eval batches — exactly an incremental session
    let gen = LmGen::new(cfg.batch, cfg.seq, cfg.vocab, cfg.eval_batches, cfg.data_seed());
    let eval = gen.eval_set();
    let mut rxs: Vec<mpsc::Receiver<Reply>> = Vec::new();
    for b in 0..cfg.batch {
        let (tx, rx) = mpsc::channel();
        for batch in eval {
            for t in 0..cfg.seq {
                let tok = batch.x[b * cfg.seq + t] as usize;
                server.submit(b as u64, tok, tx.clone()).unwrap();
            }
        }
        rxs.push(rx);
    }
    // served[b][global_t] = that step's logits
    let mut served: Vec<Vec<Vec<f32>>> = Vec::new();
    for rx in &rxs {
        let mut lane = Vec::with_capacity(eval.len() * cfg.seq);
        for _ in 0..eval.len() * cfg.seq {
            // per-session FIFO: replies arrive in submission order
            let reply = rx.recv_timeout(RECV).expect("lm reply");
            lane.push(reply.logits().expect("step reply carries logits").to_vec());
        }
        served.push(lane);
    }
    server.shutdown();

    // replay the offline eval accumulation over the served logits —
    // span by span in the fixed lane partition, each span summed
    // separately and folded in order, exactly the sharded eval's fold
    let mut loss_sum = 0f64;
    let mut count = 0usize;
    for (lo, hi) in lane_spans(cfg.batch) {
        let mut sp_loss = 0f64;
        for (k, batch) in eval.iter().enumerate() {
            for t in 0..cfg.seq {
                for b in lo..hi {
                    let y = batch.y[b * cfg.seq + t] as usize;
                    sp_loss += eval_ce(&served[b][k * cfg.seq + t], y);
                    count += 1;
                }
            }
        }
        loss_sum += sp_loss;
    }
    assert_eq!(count, want.count);
    let loss = loss_sum / count.max(1) as f64;
    assert_eq!(loss.to_bits(), want.loss.to_bits(), "served lm loss != eval loss");
    assert_eq!(loss.exp().to_bits(), want.metric.to_bits(), "served ppl != eval ppl");
}

#[test]
fn pos_checkpoint_serves_bit_identical_to_eval() {
    let mut cfg = TaskConfig::preset(TaskKind::Pos);
    cfg.vocab = 60;
    cfg.n_classes = 6;
    cfg.dim = 8;
    cfg.hidden = 10;
    cfg.batch = 4;
    cfg.seq = 8;
    cfg.eval_batches = 2;
    cfg.steps = 6;
    cfg.seed = 9;
    let ckpt = train_ckpt(cfg, "pos_parity.tensors");
    let (cfg, want) = evaluate_checkpoint(&ckpt, 1).expect("offline eval");

    let model = Arc::new(ServeModel::load(&ckpt).expect("serve auto-detects pos"));
    assert_eq!(model.task, TaskKind::Pos);
    assert_eq!(model.n_out(), cfg.n_classes, "tag head width");
    let server = Server::start(model, serve_cfg(2)).unwrap();

    let gen = PosGen::new(
        cfg.batch,
        cfg.seq,
        cfg.vocab,
        cfg.n_classes,
        cfg.eval_batches,
        cfg.data_seed(),
    );
    let eval = gen.eval_set();
    // one session per (eval batch, lane); whole sentences pipelined so
    // sequence requests co-batch across sessions
    let mut pend: Vec<(usize, usize, mpsc::Receiver<Reply>)> = Vec::new();
    for (k, batch) in eval.iter().enumerate() {
        for b in 0..cfg.batch {
            let toks: Vec<usize> =
                batch.x[b * cfg.seq..(b + 1) * cfg.seq].iter().map(|&t| t as usize).collect();
            let (tx, rx) = mpsc::channel();
            let sid = (k * cfg.batch + b) as u64;
            server.submit_sequence(sid, toks, tx).unwrap();
            pend.push((k, b, rx));
        }
    }
    // served[k][b][t] = tag scores at position t
    type LaneSteps = Vec<Vec<f32>>;
    let mut served: Vec<Vec<LaneSteps>> = vec![vec![Vec::new(); cfg.batch]; eval.len()];
    for (k, b, rx) in pend {
        let reply = rx.recv_timeout(RECV).expect("pos reply");
        match reply.payload {
            Payload::Steps { logits } => {
                assert_eq!(logits.len(), cfg.seq, "one tag-score row per position");
                served[k][b] = logits;
            }
            _ => panic!("pos sequence reply must carry per-step tag scores"),
        }
    }
    server.shutdown();

    // span-ordered fold, matching the sharded offline eval
    let mut loss_sum = 0f64;
    let mut correct = 0usize;
    let mut count = 0usize;
    for (lo, hi) in lane_spans(cfg.batch) {
        let mut sp_loss = 0f64;
        for (k, batch) in eval.iter().enumerate() {
            for t in 0..cfg.seq {
                for b in lo..hi {
                    let y = batch.y[b * cfg.seq + t] as usize;
                    let lg = &served[k][b][t];
                    sp_loss += eval_ce(lg, y);
                    correct += usize::from(argmax(lg) == y);
                    count += 1;
                }
            }
        }
        loss_sum += sp_loss;
    }
    assert_eq!(count, want.count);
    let loss = loss_sum / count.max(1) as f64;
    let metric = correct as f64 / count.max(1) as f64;
    assert_eq!(loss.to_bits(), want.loss.to_bits(), "served pos loss != eval loss");
    assert_eq!(metric.to_bits(), want.metric.to_bits(), "served tag accuracy != eval");
}

#[test]
fn nli_checkpoint_classifies_bit_identical_to_eval() {
    let mut cfg = TaskConfig::preset(TaskKind::Nli);
    cfg.vocab = 24;
    cfg.dim = 8;
    cfg.hidden = 10;
    cfg.batch = 6;
    cfg.seq = 5;
    cfg.eval_batches = 2;
    cfg.steps = 6;
    cfg.seed = 11;
    let ckpt = train_ckpt(cfg, "nli_parity.tensors");
    let (cfg, want) = evaluate_checkpoint(&ckpt, 1).expect("offline eval");

    let model = Arc::new(ServeModel::load(&ckpt).expect("serve auto-detects nli"));
    assert_eq!(model.task, TaskKind::Nli);
    assert_eq!(model.n_out(), 3, "3-way classification head");
    let server = Server::start(model, serve_cfg(2)).unwrap();

    let t_total = 2 * cfg.seq;
    let gen = NliGen::new(cfg.batch, cfg.seq, cfg.vocab, cfg.eval_batches, cfg.data_seed());
    let eval = gen.eval_set();
    // submit-sequence-then-finalize, pipelined on each session (FIFO
    // guarantees the finalize sees the sequence's final state)
    let mut pend: Vec<(usize, usize, mpsc::Receiver<Reply>)> = Vec::new();
    for (k, batch) in eval.iter().enumerate() {
        for b in 0..cfg.batch {
            let toks: Vec<usize> =
                batch.x[b * t_total..(b + 1) * t_total].iter().map(|&t| t as usize).collect();
            let (tx, rx) = mpsc::channel();
            let sid = (k * cfg.batch + b) as u64;
            server.submit_sequence(sid, toks, tx.clone()).unwrap();
            server.finalize(sid, tx).unwrap();
            pend.push((k, b, rx));
        }
    }
    // served[k][b] = the classification logits
    let mut served: Vec<Vec<Vec<f32>>> = vec![vec![Vec::new(); cfg.batch]; eval.len()];
    for (k, b, rx) in pend {
        let first = rx.recv_timeout(RECV).expect("nli prefill reply");
        assert!(
            matches!(first.payload, Payload::Prefilled { .. }),
            "sequence reply precedes the finalize reply"
        );
        let reply = rx.recv_timeout(RECV).expect("nli class reply");
        match reply.payload {
            Payload::Class { logits, label } => {
                assert_eq!(label, argmax(&logits));
                served[k][b] = logits;
            }
            _ => panic!("finalize reply must be a classification"),
        }
    }
    server.shutdown();

    // span-ordered fold, matching the sharded offline eval
    let mut loss_sum = 0f64;
    let mut correct = 0usize;
    let mut count = 0usize;
    for (lo, hi) in lane_spans(cfg.batch) {
        let mut sp_loss = 0f64;
        for (k, batch) in eval.iter().enumerate() {
            for (b, &label) in batch.y[lo..hi].iter().enumerate() {
                let y = label as usize;
                let lg = &served[k][lo + b];
                sp_loss += eval_ce(lg, y);
                correct += usize::from(argmax(lg) == y);
                count += 1;
            }
        }
        loss_sum += sp_loss;
    }
    assert_eq!(count, want.count);
    let loss = loss_sum / count.max(1) as f64;
    let metric = correct as f64 / count.max(1) as f64;
    assert_eq!(loss.to_bits(), want.loss.to_bits(), "served nli loss != eval loss");
    assert_eq!(metric.to_bits(), want.metric.to_bits(), "served accuracy != eval");
}

#[test]
fn mt_checkpoint_greedy_decode_matches_offline_reference() {
    let mut cfg = TaskConfig::preset(TaskKind::Mt);
    cfg.vocab = 16;
    cfg.vocab_tgt = 16;
    cfg.dim = 6;
    cfg.hidden = 8;
    cfg.batch = 3;
    cfg.seq = 4;
    cfg.eval_batches = 2;
    cfg.steps = 6;
    cfg.seed = 13;
    let ckpt = train_ckpt(cfg, "mt_parity.tensors");
    let (cfg, _want) = evaluate_checkpoint(&ckpt, 1).expect("offline eval");

    let model = Arc::new(ServeModel::load(&ckpt).expect("serve auto-detects mt"));
    assert_eq!(model.task, TaskKind::Mt);
    assert!(model.decoder.is_some(), "two-stack pair loaded");
    assert_eq!(model.n_out(), cfg.vocab_tgt, "replies carry decoder-head logits");
    // one shard so the concurrent decodes must share decode-loop lanes
    let server = Server::start(model.clone(), serve_cfg(1)).unwrap();

    let gen = MtGen::new(
        cfg.batch,
        cfg.seq,
        cfg.seq + 2,
        cfg.vocab,
        cfg.vocab_tgt,
        cfg.eval_batches,
        cfg.data_seed(),
    );
    let eval = gen.eval_set();
    let mut srcs: Vec<Vec<usize>> = Vec::new();
    for batch in eval {
        for b in 0..cfg.batch {
            srcs.push(
                batch.x[b * cfg.seq..(b + 1) * cfg.seq].iter().map(|&t| t as usize).collect(),
            );
        }
    }
    let max_len = cfg.seq + 2;

    // pipeline per session: encode, then greedy, beam-1, and two
    // beam-3 decodes (the encoder context is read-only for decodes,
    // so all four run from the same state)
    let mut rxs: Vec<mpsc::Receiver<Reply>> = Vec::new();
    for (i, src) in srcs.iter().enumerate() {
        let (tx, rx) = mpsc::channel();
        let sid = i as u64;
        server.submit_sequence(sid, src.clone(), tx.clone()).unwrap();
        let greedy = DecodeParams { max_len, beam_width: 1, len_norm: 0.0 };
        let beam = DecodeParams { max_len, beam_width: 3, len_norm: 0.0 };
        server.decode(sid, greedy, tx.clone()).unwrap();
        server.decode(sid, greedy, tx.clone()).unwrap();
        server.decode(sid, beam, tx.clone()).unwrap();
        server.decode(sid, beam, tx).unwrap();
        rxs.push(rx);
    }
    for (i, rx) in rxs.iter().enumerate() {
        let src = &srcs[i];
        let enc = rx.recv_timeout(RECV).expect("encode ack");
        match enc.payload {
            Payload::Encoded { consumed } => assert_eq!(consumed, src.len()),
            _ => panic!("mt sequence reply must be an encoder ack"),
        }
        let take_decoded = |rx: &mpsc::Receiver<Reply>| -> (Vec<usize>, f32) {
            match rx.recv_timeout(RECV).expect("decode reply").payload {
                Payload::Decoded { tokens, score } => (tokens, score),
                _ => panic!("decode reply must carry decoded tokens"),
            }
        };
        let (greedy_toks, greedy_score) = take_decoded(rx);
        let (greedy2_toks, greedy2_score) = take_decoded(rx);
        let (beam_toks, beam_score) = take_decoded(rx);
        let (beam2_toks, beam2_score) = take_decoded(rx);

        // greedy through the server == offline single-lane reference,
        // token-for-token and score-bit-for-score-bit — whatever lanes
        // it shared with the other sessions' decodes
        let (want_toks, want_score) =
            model.reference_greedy_decode(src, max_len).expect("reference decode");
        assert_eq!(greedy_toks, want_toks, "served greedy decode diverged (src {i})");
        assert_eq!(
            greedy_score.to_bits(),
            want_score.to_bits(),
            "greedy score bits diverged (src {i})"
        );
        // decodes are repeatable: the encoder context is not consumed
        assert_eq!(greedy2_toks, want_toks);
        assert_eq!(greedy2_score.to_bits(), want_score.to_bits());
        // beam search is deterministic; lanes retire at EOS so length
        // is bounded by (not pinned to) max_len, and an early stop
        // must be an EOS stop
        assert!(!beam_toks.is_empty() && beam_toks.len() <= max_len);
        if beam_toks.len() < max_len {
            assert_eq!(
                beam_toks.last(),
                Some(&(floatsd_lstm::data::translation::EOS as usize)),
                "beam stopped early without EOS (src {i})"
            );
        }
        assert_eq!(beam_toks, beam2_toks, "beam decode must be deterministic (src {i})");
        assert_eq!(beam_score.to_bits(), beam2_score.to_bits());
    }
    server.shutdown();
}
