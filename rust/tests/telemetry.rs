//! The telemetry determinism contract, pinned end to end: enabling
//! `--trace` must never perturb computation. Checkpoint bytes and
//! per-step loss traces are byte-identical telemetry-on vs
//! telemetry-off for all four task heads at `--threads 1` and
//! `--threads 4`; served logits are bit-identical with the telemetry
//! gate open; a fixed-seed `--trace` JSONL stream is byte-identical
//! across runs once the clearly marked `"timing"` fields are
//! stripped; and the span-sharded eval report is byte-identical for
//! any `--threads N` while carrying per-class confusion matrices.

use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use floatsd_lstm::serve::{ServeConfig, ServeModel, Server};
use floatsd_lstm::tasks::eval::{build_report, evaluate_checkpoint};
use floatsd_lstm::tasks::{TaskConfig, TaskKind, TaskTrainer};
use floatsd_lstm::telemetry::{TraceSink, TRACE_SCHEMA};
use floatsd_lstm::tensorfile::json::Json;
use floatsd_lstm::train::PresetTier;

const RECV: Duration = Duration::from_secs(30);

fn test_dir() -> PathBuf {
    let dir = std::env::temp_dir().join("fsd_telemetry_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A miniature of each task with an awkward lane count (batch 6 → six
/// 1-lane shards, so `--threads 4` chunks unevenly).
fn tiny_task_cfg(kind: TaskKind) -> TaskConfig {
    let mut cfg = TaskConfig::preset_tier(kind, PresetTier::Tiny);
    cfg.batch = 6;
    cfg.steps = 4;
    cfg.eval_batches = 2;
    cfg.log_every = 0;
    cfg.seed = 77;
    cfg
}

/// Train a tiny run, optionally traced; return per-step loss bits and
/// the checkpoint bytes.
fn run_task(kind: TaskKind, threads: usize, traced: bool) -> (Vec<u64>, Vec<u8>) {
    let dir = test_dir();
    let tag = format!("{}_{}t_{}", kind.name(), threads, if traced { "on" } else { "off" });
    let ckpt = dir.join(format!("{tag}.tensors"));
    let mut cfg = tiny_task_cfg(kind);
    cfg.threads = threads;
    cfg.checkpoint = Some(ckpt.clone());
    let trace = dir.join(format!("{tag}.jsonl"));
    if traced {
        cfg.trace = Some(trace.clone());
    }
    let mut trainer = TaskTrainer::new(cfg).expect("valid task config");
    let report = trainer.train().expect("tiny training run");
    if traced {
        let text = std::fs::read_to_string(&trace).expect("trace written");
        assert!(!text.is_empty(), "{tag}: trace stream must not be empty");
        let first = Json::parse(text.lines().next().unwrap()).expect("trace line parses");
        assert_eq!(first.get("schema").and_then(Json::as_str), Some(TRACE_SCHEMA));
        assert_eq!(first.get("ev").and_then(Json::as_str), Some("run_start"));
    }
    let bits: Vec<u64> = report.losses.iter().map(|l| l.to_bits()).collect();
    let bytes = std::fs::read(&ckpt).expect("checkpoint written");
    (bits, bytes)
}

#[test]
fn tracing_never_perturbs_training_for_any_task_or_thread_count() {
    for kind in TaskKind::ALL {
        for threads in [1usize, 4] {
            let (bits_off, bytes_off) = run_task(kind, threads, false);
            let (bits_on, bytes_on) = run_task(kind, threads, true);
            assert_eq!(
                bits_on,
                bits_off,
                "{}: loss trace diverged with --trace at --threads {threads}",
                kind.name()
            );
            assert_eq!(
                bytes_on,
                bytes_off,
                "{}: checkpoint bytes diverged with --trace at --threads {threads}",
                kind.name()
            );
        }
    }
}

/// Stream a fixed token sequence through a served LM checkpoint and
/// return every reply's logits bits, in per-session FIFO order.
fn serve_logit_bits(ckpt: &Path) -> Vec<u32> {
    let model = Arc::new(ServeModel::load(ckpt).expect("serve auto-detects lm"));
    let vocab = model.stack.embed.vocab;
    let server = Server::start(
        model,
        ServeConfig { workers: 2, max_batch: 4, batch_window: Duration::from_micros(50) },
    )
    .unwrap();
    let mut rxs = Vec::new();
    for s in 0..4u64 {
        let (tx, rx) = mpsc::channel();
        for t in 0..8usize {
            server.submit(s, (s as usize * 7 + t * 3) % vocab, tx.clone()).unwrap();
        }
        rxs.push(rx);
    }
    let mut bits = Vec::new();
    for rx in &rxs {
        for _ in 0..8 {
            let reply = rx.recv_timeout(RECV).expect("lm reply");
            let lg = reply.logits().expect("step reply carries logits");
            bits.extend(lg.iter().map(|v| v.to_bits()));
        }
    }
    server.shutdown();
    bits
}

#[test]
fn served_logits_are_bit_identical_with_telemetry_enabled() {
    let dir = test_dir();
    let ckpt = dir.join("serve_parity.tensors");
    let mut cfg = tiny_task_cfg(TaskKind::Lm);
    cfg.checkpoint = Some(ckpt.clone());
    TaskTrainer::new(cfg).unwrap().train().unwrap();

    let base = serve_logit_bits(&ckpt);
    assert!(!base.is_empty());
    // open a sink: flips the process-wide hot-path gate, so the
    // activation hooks count during this serve run
    let trace = dir.join("serve_parity.jsonl");
    let mut sink = TraceSink::create(&trace).unwrap();
    let gated = serve_logit_bits(&ckpt);
    sink.finish().unwrap();
    drop(sink);
    assert_eq!(gated, base, "served logits changed with the telemetry gate open");
}

/// Parse a JSONL trace, drop the wall-clock-only `"timing"` fields,
/// and return the re-serialized deterministic lines.
fn deterministic_lines(path: &Path) -> Vec<String> {
    let text = std::fs::read_to_string(path).expect("read trace");
    text.lines()
        .map(|line| {
            let mut j = Json::parse(line).expect("trace line parses");
            if let Json::Obj(m) = &mut j {
                m.remove("timing");
            }
            j.to_string()
        })
        .collect()
}

#[test]
fn cli_trace_stream_is_byte_deterministic_across_runs() {
    let dir = test_dir();
    let run = |n: usize| -> PathBuf {
        let trace = dir.join(format!("cli_trace_{n}.jsonl"));
        let out = dir.join(format!("cli_trace_{n}.tensors"));
        // an absurd initial loss scale forces overflow skips, so the
        // stream is guaranteed to carry loss_scale backoff events
        let status = Command::new(env!("CARGO_BIN_EXE_floatsd-lstm"))
            .args([
                "train",
                "--preset",
                "tiny",
                "--steps",
                "8",
                "--seed",
                "5",
                "--log-every",
                "0",
                "--loss-scale",
                "1000000000",
            ])
            .arg("--out")
            .arg(&out)
            .arg("--trace")
            .arg(&trace)
            .status()
            .expect("spawn floatsd-lstm train");
        assert!(status.success(), "traced training run failed");
        trace
    };
    let t1 = run(1);
    let t2 = run(2);
    let l1 = deterministic_lines(&t1);
    let l2 = deterministic_lines(&t2);
    assert_eq!(l1, l2, "fixed-seed trace streams diverged beyond timing fields");

    let evs: Vec<String> = l1
        .iter()
        .map(|l| {
            let j = Json::parse(l).unwrap();
            j.get("ev").and_then(Json::as_str).unwrap_or("?").to_string()
        })
        .collect();
    assert_eq!(evs.first().map(String::as_str), Some("run_start"));
    assert_eq!(evs.last().map(String::as_str), Some("run_end"));
    assert!(evs.iter().any(|e| e == "step"), "no step events: {evs:?}");
    assert!(evs.iter().any(|e| e == "loss_scale"), "no loss_scale events: {evs:?}");

    // the report summarizer digests the same stream
    let out = Command::new(env!("CARGO_BIN_EXE_floatsd-lstm"))
        .arg("report")
        .arg(&t1)
        .output()
        .expect("spawn floatsd-lstm report");
    assert!(out.status.success(), "report failed: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("loss scale:"), "report missing loss-scale section: {text}");
    assert!(text.contains("backoffs"), "report missing backoff count: {text}");
    assert!(
        text.contains("floatsd8 weight saturation"),
        "report missing re-encode section: {text}"
    );
}

/// Every element of `sub` appears in `full`, in order (two-pointer
/// scan). Strictness — `sub` being genuinely smaller — is asserted
/// separately so a failure names which property broke.
fn is_subsequence(sub: &[String], full: &[String]) -> bool {
    let mut it = full.iter();
    sub.iter().all(|line| it.any(|f| f == line))
}

#[test]
fn trace_every_samples_a_strict_subsequence_of_the_full_stream() {
    let dir = test_dir();
    let run = |label: &str, extra: &[&str], every: usize| -> (PathBuf, PathBuf) {
        let trace = dir.join(format!("every_{label}_{every}.jsonl"));
        let out = dir.join(format!("every_{label}_{every}.tensors"));
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_floatsd-lstm"));
        cmd.args(["train", "--preset", "tiny", "--log-every", "0", "--seed", "5"]);
        cmd.args(extra);
        cmd.args(["--trace-every", &every.to_string()]);
        cmd.arg("--out").arg(&out).arg("--trace").arg(&trace);
        let status = cmd.status().expect("spawn floatsd-lstm train");
        assert!(status.success(), "traced run failed ({label}, --trace-every {every})");
        (trace, out)
    };
    // both offline trainers honor --trace-every: the char-LM path (with
    // an absurd loss scale so backoff events are in the stream) and the
    // multi-task path
    let char_extra: &[&str] = &["--steps", "8", "--loss-scale", "1000000000"];
    let task_extra: &[&str] = &["--task", "pos", "--steps", "6"];
    for (label, extra, steps) in [("char", char_extra, 8usize), ("task", task_extra, 6)] {
        let (t_full, o_full) = run(label, extra, 1);
        let (t_smp, o_smp) = run(label, extra, 3);
        let full = deterministic_lines(&t_full);
        let sampled = deterministic_lines(&t_smp);

        // sampling drops lines, never rewrites them: the sampled stream
        // is a strict subsequence of the N=1 stream
        assert!(
            sampled.len() < full.len(),
            "{label}: --trace-every 3 stream is not smaller ({} vs {})",
            sampled.len(),
            full.len()
        );
        assert!(
            is_subsequence(&sampled, &full),
            "{label}: sampled stream is not a subsequence of the full stream"
        );

        let evs = |lines: &[String]| -> Vec<String> {
            lines
                .iter()
                .map(|l| {
                    let j = Json::parse(l).unwrap();
                    j.get("ev").and_then(Json::as_str).unwrap_or("?").to_string()
                })
                .collect()
        };
        let evs_full = evs(&full);
        let evs_smp = evs(&sampled);
        // run bracketing always survives sampling
        assert_eq!(evs_smp.first().map(String::as_str), Some("run_start"));
        assert_eq!(evs_smp.last().map(String::as_str), Some("run_end"));
        // exactly every 3rd step keeps its step event
        let count = |evs: &[String], which: &str| evs.iter().filter(|e| *e == which).count();
        assert_eq!(count(&evs_full, "step"), steps, "{label}: N=1 must trace every step");
        assert_eq!(count(&evs_smp, "step"), steps / 3, "{label}: sampled step count");
        // loss-scale events are never sampled away
        assert_eq!(
            count(&evs_smp, "loss_scale"),
            count(&evs_full, "loss_scale"),
            "{label}: loss_scale events must always emit"
        );

        // sampling is numerics-neutral: same checkpoint bytes
        let full_bytes = std::fs::read(&o_full).unwrap();
        let smp_bytes = std::fs::read(&o_smp).unwrap();
        assert_eq!(smp_bytes, full_bytes, "{label}: --trace-every changed the checkpoint");
    }
}

#[test]
fn eval_report_is_byte_identical_across_thread_counts() {
    let dir = test_dir();
    let ckpt = dir.join("eval_threads.tensors");
    let mut cfg = tiny_task_cfg(TaskKind::Pos);
    cfg.checkpoint = Some(ckpt.clone());
    TaskTrainer::new(cfg).unwrap().train().unwrap();

    let (_c1, e1) = evaluate_checkpoint(&ckpt, 1).expect("eval at 1 thread");
    let (_c4, e4) = evaluate_checkpoint(&ckpt, 4).expect("eval at 4 threads");
    assert_eq!(e1.loss.to_bits(), e4.loss.to_bits(), "sharded eval loss diverged");
    assert_eq!(e1.metric.to_bits(), e4.metric.to_bits(), "sharded eval metric diverged");
    let cm = e1.confusion.as_ref().expect("pos eval carries a confusion matrix");
    assert_eq!(cm.total(), e1.count as u64, "confusion cells must sum to the scored count");
    assert_eq!(e1.confusion, e4.confusion, "confusion matrices diverged across threads");

    let models = vec![ckpt];
    let r1 = build_report(&models, 1).expect("report at 1 thread").to_string();
    let r4 = build_report(&models, 4).expect("report at 4 threads").to_string();
    assert_eq!(r1, r4, "eval report bytes diverged across --threads");
    assert!(r1.contains("\"confusion\":"), "report missing confusion matrices");
}
