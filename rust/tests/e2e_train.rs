//! End-to-end integration: rust coordinator → PJRT → AOT train step.
//! Requires `make artifacts` (tests skip politely otherwise).

use floatsd_lstm::coordinator::{run_experiment, ExperimentSpec};
use floatsd_lstm::config::TrainPreset;
use floatsd_lstm::data::make_source;
use floatsd_lstm::lstm::model::{build_tiny_from_params, ParamBag};
use floatsd_lstm::runtime::{Runtime, TrainSession};
use floatsd_lstm::tensorfile::read_tensors;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

#[test]
fn tiny_quantized_training_reduces_loss_via_pjrt() {
    let Some(mut rt) = runtime() else { return };
    let mut session = TrainSession::new(&mut rt, "tiny_fsd8m16").expect("session");
    let task = session.task.clone();
    let mut src = make_source(
        &task.name, task.batch, &task.x_shape, &task.y_shape,
        task.vocab, task.vocab_tgt, task.n_classes, 2, 99,
    )
    .unwrap();
    // average the first and last 10 steps (single-batch losses are noisy)
    let mut losses = Vec::new();
    for _ in 0..450 {
        let b = src.next_train();
        let m = session.step(&b).expect("step");
        let loss = m.mean_loss();
        assert!(loss.is_finite(), "loss must stay finite");
        losses.push(loss);
    }
    let head: f32 = losses[..10].iter().sum::<f32>() / 10.0;
    let tail: f32 = losses[losses.len() - 10..].iter().sum::<f32>() / 10.0;
    assert!(
        tail < head * 0.95,
        "quantized training did not learn: {head} -> {tail}"
    );
}

#[test]
fn fp32_and_quantized_share_init_and_both_run() {
    let Some(mut rt) = runtime() else { return };
    let mut a = TrainSession::new(&mut rt, "tiny_fp32").expect("fp32");
    let mut b = TrainSession::new(&mut rt, "tiny_fsd8m16").expect("fsd8m16");
    let mut src = make_source("tiny", 8, &[8], &[8], 64, 0, 0, 1, 7).unwrap();
    let batch = src.next_train();
    let ma = a.step(&batch).unwrap();
    let mb = b.step(&batch).unwrap();
    // same init, same data: losses start in the same neighbourhood but
    // are NOT identical (quantization is active)
    assert!((ma.mean_loss() - mb.mean_loss()).abs() < 0.5);
    assert_ne!(ma.loss_sum.to_bits(), mb.loss_sum.to_bits());
}

#[test]
fn eval_is_deterministic() {
    let Some(mut rt) = runtime() else { return };
    let session = TrainSession::new(&mut rt, "tiny_fp32").expect("session");
    let src = make_source("tiny", 8, &[8], &[8], 64, 0, 0, 3, 5).unwrap();
    let e1 = session.eval(src.eval_set()).unwrap();
    let e2 = session.eval(src.eval_set()).unwrap();
    assert_eq!(e1.loss_sum.to_bits(), e2.loss_sum.to_bits());
    assert!(e1.count > 0.0);
}

#[test]
fn checkpoint_round_trip_and_engine_load() {
    let Some(mut rt) = runtime() else { return };
    let session = TrainSession::new(&mut rt, "tiny_fsd8m16").expect("session");
    let dir = std::env::temp_dir().join("fsd_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tiny.ckpt.tensors");
    session.save_checkpoint(&path).expect("save");

    // the rust inference engine can consume the same state file
    let bag = ParamBag::from_tensors(read_tensors(&path).unwrap());
    let stack = build_tiny_from_params(&bag).expect("assemble engine");
    let logits = stack.forward(&[1, 2, 3, 4]);
    assert_eq!(logits.len(), 4);
    assert_eq!(logits[0].len(), 64);
    assert!(logits.iter().flatten().all(|v| v.is_finite()));
}

#[test]
fn experiment_runner_produces_monotone_epochs_and_logs() {
    let Some(mut rt) = runtime() else { return };
    let spec = ExperimentSpec {
        artifact: "tiny_fp32".into(),
        preset: TrainPreset { epochs: 2, steps_per_epoch: 5, eval_batches: 2 },
        data_seed: 11,
        log: true,
    };
    let res = run_experiment(&mut rt, &spec).expect("experiment");
    assert_eq!(res.curve.len(), 2);
    assert_eq!(res.steps, 10);
    let csv = floatsd_lstm::benchlib::results_dir().join("curves/tiny_fp32.csv");
    assert!(csv.exists(), "curve CSV missing");
}

#[test]
fn engine_matches_pjrt_eval_loss_roughly() {
    // Cross-validation of the rust engine against the AOT eval graph on
    // the *same* weights: the engine is hardware-disciplined while the
    // L2 graph models at the dot boundary, so we compare the resulting
    // mean loss within a coarse tolerance (they share grids everywhere
    // else). This catches layout/transpose mistakes immediately.
    let Some(mut rt) = runtime() else { return };
    let session = TrainSession::new(&mut rt, "tiny_fsd8m16").expect("session");
    let src = make_source("tiny", 8, &[8], &[8], 64, 0, 0, 1, 13).unwrap();
    let batch = &src.eval_set()[0];
    let pjrt = session.eval(std::slice::from_ref(batch)).unwrap();

    let dir = std::env::temp_dir().join("fsd_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cross.tensors");
    session.save_checkpoint(&path).unwrap();
    let bag = ParamBag::from_tensors(read_tensors(&path).unwrap());
    let stack = build_tiny_from_params(&bag).unwrap();

    let mut loss_sum = 0f64;
    let mut count = 0f64;
    for b in 0..8 {
        let ids: Vec<usize> = batch.x[b * 8..(b + 1) * 8].iter().map(|&t| t as usize).collect();
        let logits = stack.forward(&ids);
        for (t, lg) in logits.iter().enumerate() {
            let y = batch.y[b * 8 + t] as usize;
            let mx = lg.iter().cloned().fold(f32::MIN, f32::max);
            let lse: f32 = lg.iter().map(|v| (v - mx).exp()).sum::<f32>().ln() + mx;
            loss_sum += (lse - lg[y]) as f64;
            count += 1.0;
        }
    }
    let engine_loss = (loss_sum / count) as f32;
    let pjrt_loss = pjrt.mean_loss();
    assert!(
        (engine_loss - pjrt_loss).abs() < 0.15 * pjrt_loss.max(1.0),
        "engine {engine_loss} vs pjrt {pjrt_loss}"
    );
}
