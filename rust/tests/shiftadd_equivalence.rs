//! The shift-add kernel tier's equivalence contract, pinned end to
//! end (`qmath::shiftadd` docs):
//!
//! * kernel level — `--kernel-tier shiftadd` matvec/matmul are
//!   **bit-identical** to the decoded-f32 reference over all 256
//!   FloatSD8 codes and every activation class (FP8-grid, off-grid,
//!   f32 denormals, huge magnitudes, ±0, ±inf, NaN);
//! * hardware level — the shift-add group agrees bit-for-bit with the
//!   five-stage MAC pipeline simulator, and its digit expansion
//!   value-matches the pipeline's stage-1 partial products;
//! * system level — fixed-seed train/serve/eval runs under the
//!   shiftadd tier reproduce the decoded tier exactly for all four
//!   task heads (loss bits, checkpoint bytes, report bytes, decode
//!   tokens/scores);
//! * ISA level — the runtime-dispatched SIMD paths (`qmath::simd`:
//!   sse2, avx2) are pinned bit-identical to the scalar path on both
//!   tiers, at every forced tile width, across padded-stride shapes
//!   and the adversarial activation classes, and end to end (training
//!   loss bits + checkpoint bytes, eval report bytes, streamed logits,
//!   decode tokens) — ISAs the host lacks are skipped with a notice;
//! * the whole-row single-rounding variant `dot_row_sa_wide` is *not*
//!   pinned — its divergence from the chained reference is
//!   characterized by an explicit error bound instead.

use std::path::PathBuf;
use std::sync::Arc;

use floatsd_lstm::formats::{round_f16, round_f8, FloatSd8, Fp16, Fp8, FLOAT_SD8};
use floatsd_lstm::hardware::mac_sim::MacPipeline;
use floatsd_lstm::lstm::synthetic_stack;
use floatsd_lstm::qmath::mac::MAC_GROUP;
use floatsd_lstm::qmath::shiftadd::{decompose_x, dot_row_sa_wide, WeightDigits};
use floatsd_lstm::qmath::vector::{matmul_fast, matmul_isa, matmul_tiled, matvec_fast, QMatrix};
use floatsd_lstm::qmath::{IsaPath, KernelTier};
use floatsd_lstm::rng::SplitMix64;
use floatsd_lstm::serve::ServeModel;
use floatsd_lstm::tasks::eval::{build_report_exec, build_report_tier};
use floatsd_lstm::tasks::{TaskConfig, TaskKind, TaskTrainer};
use floatsd_lstm::train::PresetTier;

fn test_dir() -> PathBuf {
    let dir = std::env::temp_dir().join("fsd_shiftadd_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A 16x16 matrix holding **every** FloatSD8 code exactly once —
/// including the non-canonical rank-31 codes, which must clamp the
/// same way on both tiers.
fn all_codes_matrix() -> QMatrix {
    let codes: Vec<FloatSd8> = (0..=u8::MAX).map(FloatSd8).collect();
    QMatrix::from_codes(16, 16, codes)
}

/// Run one matvec on both tiers and require bit-identical outputs.
fn assert_matvec_parity(w: &mut QMatrix, x: &[f32], bias: &[f32], what: &str) {
    let mut dec = vec![0f32; w.rows];
    let mut sa = vec![0f32; w.rows];
    w.set_kernel_tier(KernelTier::Decoded);
    matvec_fast(w, x, bias, &mut dec);
    w.set_kernel_tier(KernelTier::ShiftAdd);
    matvec_fast(w, x, bias, &mut sa);
    for r in 0..w.rows {
        assert_eq!(
            sa[r].to_bits(),
            dec[r].to_bits(),
            "{what}: row {r} diverged (decoded {} vs shiftadd {})",
            dec[r],
            sa[r]
        );
    }
}

/// The adversarial operand classes the fallback rule must catch:
/// f32 denormals (below the frame LSB), the denormal boundary,
/// magnitudes past the frame cap, non-finite values, signed zero.
fn adversarial_activations() -> Vec<f32> {
    vec![
        0.0,
        -0.0,
        f32::MIN_POSITIVE,        // 2^-126
        1e-41,                    // f32 denormal
        -(2f32.powi(-149)),       // smallest denormal
        2f32.powi(-19),           // last in-frame activation octave
        -(2f32.powi(-20)),        // first out-of-frame octave
        65504.0,                  // FP16 max
        114688.0,                 // FP8 max
        2f32.powi(20),            // frame magnitude cap
        2f32.powi(21),            // just past the cap
        3e7,
        -1e30,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::NAN,
    ]
}

#[test]
fn all_256_codes_match_decoded_for_every_activation_class() {
    let mut w = all_codes_matrix();
    let mut rng = SplitMix64::new(0xC0DE);
    let cols = w.cols;

    let specials = adversarial_activations();

    // pure-class sweeps: each special value broadcast across a vector
    for (i, &v) in specials.iter().enumerate() {
        let x = vec![v; cols];
        let bias: Vec<f32> = (0..w.rows).map(|_| round_f16(rng.uniform(-0.5, 0.5))).collect();
        assert_matvec_parity(&mut w, &x, &bias, &format!("special #{i} ({v})"));
    }

    // mixed sweeps: specials scattered among grid/off-grid randoms, so
    // fast and fallback groups interleave within one row
    for trial in 0..64 {
        let x: Vec<f32> = (0..cols)
            .map(|c| match (trial + c) % 4 {
                0 => specials[rng.uniform(0.0, specials.len() as f32) as usize % specials.len()],
                1 => round_f8(rng.uniform(-4.0, 4.0)),
                2 => rng.uniform(-1.0, 1.0), // off-grid f32
                _ => rng.uniform(-1.0, 1.0) * 2f32.powi(trial as i32 % 45 - 22),
            })
            .collect();
        let bias: Vec<f32> = (0..w.rows).map(|_| round_f16(rng.uniform(-2.0, 2.0))).collect();
        assert_matvec_parity(&mut w, &x, &bias, &format!("mixed trial {trial}"));
    }
}

#[test]
fn awkward_shapes_and_batches_match_decoded() {
    let mut rng = SplitMix64::new(77);
    // cols off the MAC_GROUP boundary, a degenerate 1x1, widths that
    // land just below / on / above the digit planes' 16-lane padded
    // stride (15/16/17, 31, 48), and every batch size across both
    // register-tile widths (1..=17 crosses 8-, 4- and scalar-tile
    // dispatch) — all swept at every forced tile cap on both tiers.
    for &(rows, cols) in &[
        (6usize, 12usize),
        (3, 7),
        (9, 5),
        (1, 1),
        (5, 33),
        (4, 15),
        (4, 16),
        (4, 17),
        (3, 31),
        (2, 48),
    ] {
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut w = QMatrix::from_f32(rows, cols, &data);
        let bias: Vec<f32> = (0..rows).map(|_| round_f16(rng.uniform(-0.5, 0.5))).collect();
        for batch in 1usize..=17 {
            let xs: Vec<f32> = (0..batch * cols)
                .map(|_| rng.uniform(-1.0, 1.0) * 2f32.powi(rng.uniform(0.0, 30.0) as i32 - 15))
                .collect();
            let mut dec = vec![0f32; batch * rows];
            let mut sa = vec![0f32; batch * rows];
            w.set_kernel_tier(KernelTier::Decoded);
            matmul_fast(&w, &xs, batch, &bias, &mut dec);
            w.set_kernel_tier(KernelTier::ShiftAdd);
            matmul_fast(&w, &xs, batch, &bias, &mut sa);
            let dec_bits: Vec<u32> = dec.iter().map(|v| v.to_bits()).collect();
            let sa_bits: Vec<u32> = sa.iter().map(|v| v.to_bits()).collect();
            assert_eq!(sa_bits, dec_bits, "({rows}x{cols}) batch {batch} diverged");
            // capped tile widths reproduce the full kernel on both tiers
            for max_tile in [1usize, 4, 8] {
                for (tier, want) in
                    [(KernelTier::Decoded, &dec_bits), (KernelTier::ShiftAdd, &sa_bits)]
                {
                    w.set_kernel_tier(tier);
                    let mut tiled = vec![0f32; batch * rows];
                    matmul_tiled(&w, &xs, batch, &bias, &mut tiled, max_tile);
                    let tiled_bits: Vec<u32> = tiled.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(
                        &tiled_bits,
                        want,
                        "({rows}x{cols}) batch {batch} tile {max_tile} {} diverged",
                        tier.name()
                    );
                }
            }
        }
    }
}

/// The chained decoded reference for one row — re-derived here (not
/// imported) so the test states the contract independently.
fn chained_reference(row: &[f32], x: &[f32], bias: f32) -> f32 {
    let mut acc = bias;
    for chunk in 0..row.len().div_ceil(MAC_GROUP) {
        let lo = chunk * MAC_GROUP;
        let hi = (lo + MAC_GROUP).min(row.len());
        let mut g = 0f64;
        for c in lo..hi {
            g += x[c] as f64 * row[c] as f64;
        }
        acc = Fp16::from_f64(acc as f64 + g).to_f32();
    }
    acc
}

#[test]
fn wide_variant_is_single_rounding_with_characterized_divergence() {
    let mut rng = SplitMix64::new(11);
    let mut saw_divergence = false;
    for trial in 0..2000 {
        let cols = 1 + (trial % 40);
        let codes: Vec<FloatSd8> =
            (0..cols).map(|_| FLOAT_SD8.encode(rng.uniform(-4.5, 4.5))).collect();
        let dig: Vec<WeightDigits> = codes.iter().map(|&c| WeightDigits::of(c)).collect();
        let row: Vec<f32> = codes.iter().map(|&c| FLOAT_SD8.decode(c)).collect();
        let x: Vec<f32> = (0..cols).map(|_| round_f8(rng.uniform(-4.0, 4.0))).collect();
        let xt: Vec<_> = x.iter().map(|&v| decompose_x(v)).collect();
        let bias = round_f16(rng.uniform(-1.0, 1.0));

        // exact value: every product is an exact multiple of 2^-28 and
        // the magnitudes here keep the f64 sum well under 53 bits
        let exact: f64 =
            bias as f64 + row.iter().zip(&x).map(|(&w, &v)| w as f64 * v as f64).sum::<f64>();

        // (a) the wide variant IS "round the exact value once"
        let wide = dot_row_sa_wide(&dig, &xt, bias).expect("in-frame operands");
        assert_eq!(
            wide.to_bits(),
            Fp16::from_f64(exact).to_f32().to_bits(),
            "trial {trial}: wide != RNE(exact sum)"
        );

        // (b) divergence from the chained reference is bounded by the
        // per-group roundings the wide variant skips: each of the
        // n_groups+1 roundings moves the running value by at most half
        // an FP16 ULP (2^-11 relative, 2^-25 absolute floor)
        let chained = chained_reference(&row, &x, bias);
        let groups = cols.div_ceil(MAC_GROUP) as f64;
        let mut run = bias as f64;
        let mut mag = run.abs();
        for chunk in 0..cols.div_ceil(MAC_GROUP) {
            let lo = chunk * MAC_GROUP;
            let hi = (lo + MAC_GROUP).min(cols);
            for c in lo..hi {
                run += x[c] as f64 * row[c] as f64;
            }
            mag = mag.max(run.abs());
        }
        let bound = 2.0 * (groups + 1.0) * (mag * 2f64.powi(-11) + 2f64.powi(-24));
        let diff = (wide as f64 - chained as f64).abs();
        assert!(
            diff <= bound,
            "trial {trial}: |wide - chained| = {diff} exceeds bound {bound} (mag {mag})"
        );
        saw_divergence |= diff != 0.0;
    }
    // the envelope is genuinely non-zero: the wide variant is a
    // different rounding schedule, not a disguised identity
    assert!(saw_divergence, "wide variant never diverged from the chained reference");

    // out-of-frame operands refuse rather than silently degrade
    let dig = [WeightDigits::of(FLOAT_SD8.encode(1.0))];
    for bad in [f32::NAN, f32::INFINITY, 1e-41, 2f32.powi(21)] {
        assert!(
            dot_row_sa_wide(&dig, &[decompose_x(bad)], 0.0).is_none(),
            "x = {bad} must be rejected"
        );
    }
}

#[test]
fn shiftadd_group_matches_hardware_mac_pipeline() {
    let mut rng = SplitMix64::new(21);
    for trial in 0..20_000 {
        let n = 1 + (trial % MAC_GROUP);
        let xs8: Vec<Fp8> =
            (0..n).map(|_| Fp8::from_f32(rng.uniform(-200.0, 200.0))).collect();
        let ws: Vec<FloatSd8> =
            (0..n).map(|_| FLOAT_SD8.encode(rng.uniform(-4.5, 4.5))).collect();
        let acc = Fp16::from_f32(rng.uniform(-32.0, 32.0));

        // one ≤4-column row is exactly one MAC group, so the shiftadd
        // matvec result must equal the pipeline's combinational output
        let mut w = QMatrix::from_codes(1, n, ws.clone());
        w.set_kernel_tier(KernelTier::ShiftAdd);
        let x: Vec<f32> = xs8.iter().map(|v| v.to_f32()).collect();
        let mut out = [0f32];
        matvec_fast(&w, &x, &[acc.to_f32()], &mut out);
        let hw = MacPipeline::compute(acc, &xs8, &ws);
        assert_eq!(
            out[0].to_bits(),
            hw.to_f32().to_bits(),
            "trial {trial}: shiftadd group {} != pipeline {}",
            out[0],
            hw.to_f32()
        );
    }
}

#[test]
fn digit_expansion_value_matches_pipeline_partial_products() {
    // for every code and a spread of FP8 activations, the shift-add
    // digit expansion (digit × activation) must produce the same
    // partial-product values stage 1 of the pipeline generates
    let mut rng = SplitMix64::new(31);
    let xs: Vec<Fp8> = (0..24)
        .map(|i| {
            if i < 4 {
                Fp8::from_f32([0.0, 1.0, -2.5, 96.0][i])
            } else {
                Fp8::from_f32(rng.uniform(-300.0, 300.0))
            }
        })
        .collect();
    for bits in 0..=u8::MAX {
        let code = FloatSd8(bits);
        let d = WeightDigits::of(code);
        for &x in &xs {
            let s1 = MacPipeline::stage1(Fp16::ZERO, &[x], &[code]);
            let mut hw: Vec<f64> =
                s1.pps.iter().map(|p| p.sig as f64 * 2f64.powi(p.exp)).collect();
            let mut sa: Vec<f64> = [(d.s0, d.e0), (d.s1, d.e1)]
                .iter()
                .filter(|(s, _)| *s != 0)
                .map(|&(s, e)| s as f64 * 2f64.powi(e as i32) * x.to_f32() as f64)
                .collect();
            hw.sort_by(|a, b| a.partial_cmp(b).unwrap());
            sa.sort_by(|a, b| a.partial_cmp(b).unwrap());
            assert_eq!(hw.len(), sa.len(), "code {bits:#04x} x {}", x.to_f32());
            for (a, b) in hw.iter().zip(&sa) {
                assert_eq!(a, b, "code {bits:#04x} x {}: pp {hw:?} vs digits {sa:?}", x.to_f32());
            }
        }
    }
}

/// A miniature fixed-seed run of each task (the telemetry suite's
/// scale) with a selectable kernel tier.
fn tiny_cfg(kind: TaskKind, tier: KernelTier) -> TaskConfig {
    let mut cfg = TaskConfig::preset_tier(kind, PresetTier::Tiny);
    cfg.batch = 6;
    cfg.steps = 4;
    cfg.eval_batches = 2;
    cfg.log_every = 0;
    cfg.seed = 99;
    cfg.kernel_tier = tier;
    cfg
}

#[test]
fn training_under_shiftadd_reproduces_decoded_for_all_tasks() {
    let dir = test_dir();
    for kind in TaskKind::ALL {
        let mut runs = Vec::new();
        for tier in [KernelTier::Decoded, KernelTier::ShiftAdd] {
            let ckpt = dir.join(format!("train_{}_{}.tensors", kind.name(), tier.name()));
            let mut cfg = tiny_cfg(kind, tier);
            cfg.checkpoint = Some(ckpt.clone());
            let report = TaskTrainer::new(cfg).unwrap().train().unwrap();
            let bits: Vec<u64> = report.losses.iter().map(|l| l.to_bits()).collect();
            runs.push((bits, std::fs::read(&ckpt).unwrap()));
        }
        assert_eq!(runs[1].0, runs[0].0, "{}: loss trace diverged under shiftadd", kind.name());
        assert_eq!(
            runs[1].1,
            runs[0].1,
            "{}: checkpoint bytes diverged under shiftadd",
            kind.name()
        );
    }
}

#[test]
fn eval_report_bytes_are_tier_invariant() {
    let dir = test_dir();
    let ckpt = dir.join("eval_tier.tensors");
    let mut cfg = tiny_cfg(TaskKind::Pos, KernelTier::Decoded);
    cfg.checkpoint = Some(ckpt.clone());
    TaskTrainer::new(cfg).unwrap().train().unwrap();

    let models = vec![ckpt];
    let dec = build_report_tier(&models, 1, KernelTier::Decoded).unwrap().to_string();
    let sa = build_report_tier(&models, 1, KernelTier::ShiftAdd).unwrap().to_string();
    assert_eq!(sa, dec, "eval report bytes diverged across kernel tiers");
    assert!(!dec.contains("shiftadd"), "tier must never leak into the report");
}

#[test]
fn served_model_decodes_identically_under_shiftadd() {
    let dir = test_dir();
    let ckpt = dir.join("serve_tier_mt.tensors");
    let mut cfg = tiny_cfg(TaskKind::Mt, KernelTier::Decoded);
    cfg.checkpoint = Some(ckpt.clone());
    TaskTrainer::new(cfg).unwrap().train().unwrap();

    let src: Vec<usize> = vec![3, 1, 7, 2];
    let mut results = Vec::new();
    for tier in [KernelTier::Decoded, KernelTier::ShiftAdd] {
        let mut model = ServeModel::load(&ckpt).expect("mt checkpoint loads");
        model.set_kernel_tier(tier).expect("exclusive at load time");
        let (tokens, score) = model.reference_greedy_decode(&src, 8).unwrap();
        results.push((tokens, score.to_bits()));
    }
    assert_eq!(results[1].0, results[0].0, "decoded tokens diverged under shiftadd");
    assert_eq!(results[1].1, results[0].1, "decode score bits diverged under shiftadd");
}

#[test]
fn streamed_logits_are_tier_invariant_and_tier_set_is_load_time_only() {
    // single-stack (lm) parity through the streaming forward
    let mut bits = Vec::new();
    for tier in [KernelTier::Decoded, KernelTier::ShiftAdd] {
        let mut model =
            ServeModel::lm(Arc::new(synthetic_stack(16, 4, 6, 1, 16, 3))).unwrap();
        model.set_kernel_tier(tier).unwrap();
        let mut state = model.stack.new_stream_state();
        let logits = model.stack.forward_from(&[1, 5, 9, 13, 2], &mut state);
        bits.push(
            logits.iter().flat_map(|row| row.iter().map(|v| v.to_bits())).collect::<Vec<u32>>(),
        );
    }
    assert_eq!(bits[1], bits[0], "streamed lm logits diverged under shiftadd");

    // once the stacks are shared (a worker cloned the Arc), switching
    // tiers must refuse instead of racing the hot path
    let mut model = ServeModel::lm(Arc::new(synthetic_stack(16, 4, 6, 1, 16, 3))).unwrap();
    let _alias = model.stack.clone();
    let err = model.set_kernel_tier(KernelTier::ShiftAdd).expect_err("aliased stack");
    assert!(err.to_string().contains("before the model is shared"), "got: {err}");
}

// ---------------------------------------------------------------------
// ISA dispatch parity (qmath::simd)
// ---------------------------------------------------------------------

/// Every ISA the host can run, scalar first; prints a notice for each
/// path the host lacks instead of silently shrinking coverage.
fn available_isas() -> Vec<IsaPath> {
    let isas: Vec<IsaPath> = [IsaPath::Scalar, IsaPath::Sse2, IsaPath::Avx2]
        .into_iter()
        .filter(|i| i.available())
        .collect();
    for missing in [IsaPath::Sse2, IsaPath::Avx2] {
        if !isas.contains(&missing) {
            eprintln!(
                "note: {} unsupported on this host — its parity lanes are skipped",
                missing.name()
            );
        }
    }
    isas
}

/// `scalar` plus the widest ISA the host dispatches — the end-to-end
/// pair the auto path actually exercises.
fn isa_pair() -> Vec<IsaPath> {
    let mut v = vec![IsaPath::Scalar];
    if IsaPath::detect() != IsaPath::Scalar {
        v.push(IsaPath::detect());
    }
    v
}

#[test]
fn forced_isa_sweeps_are_bit_identical_to_scalar_on_both_tiers() {
    let isas = available_isas();
    let mut rng = SplitMix64::new(0x51D);
    // the same adversarial operand classes the tier sweep uses — every
    // SIMD lane must reproduce the scalar fallback decisions exactly
    let specials = adversarial_activations();
    // widths just below / on / above the digit planes' 16-lane padded
    // stride (15/16/17, 31, 48) plus off-MAC_GROUP shapes
    for &(rows, cols) in &[
        (4usize, 15usize),
        (4, 16),
        (4, 17),
        (3, 31),
        (2, 48),
        (5, 33),
        (3, 7),
    ] {
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut w = QMatrix::from_f32(rows, cols, &data);
        let bias: Vec<f32> = (0..rows).map(|_| round_f16(rng.uniform(-0.5, 0.5))).collect();
        for tier in [KernelTier::Decoded, KernelTier::ShiftAdd] {
            w.set_kernel_tier(tier);
            for batch in 1usize..=17 {
                // specials scattered among grid and off-grid randoms so
                // fast and fallback groups interleave inside the tiles
                let xs: Vec<f32> = (0..batch * cols)
                    .map(|i| match i % 4 {
                        0 => specials[(batch + i) % specials.len()],
                        1 => round_f8(rng.uniform(-4.0, 4.0)),
                        _ => rng.uniform(-1.0, 1.0) * 2f32.powi(i as i32 % 45 - 22),
                    })
                    .collect();
                for max_tile in [1usize, 4, 8] {
                    let mut want = vec![0f32; batch * rows];
                    matmul_isa(&w, &xs, batch, &bias, &mut want, max_tile, IsaPath::Scalar);
                    let want: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                    for &isa in &isas {
                        let mut got = vec![0f32; batch * rows];
                        matmul_isa(&w, &xs, batch, &bias, &mut got, max_tile, isa);
                        let got: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                        assert_eq!(
                            got,
                            want,
                            "({rows}x{cols}) batch {batch} tile {max_tile} {} {} diverged",
                            tier.name(),
                            isa.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn training_is_isa_invariant_for_all_tasks_on_both_tiers() {
    let dir = test_dir();
    let isas = isa_pair();
    if isas.len() == 1 {
        eprintln!("note: scalar-only host — cross-ISA training runs would be identical builds");
        return;
    }
    for kind in TaskKind::ALL {
        for tier in [KernelTier::Decoded, KernelTier::ShiftAdd] {
            let mut runs = Vec::new();
            for &isa in &isas {
                let ckpt = dir.join(format!(
                    "train_isa_{}_{}_{}.tensors",
                    kind.name(),
                    tier.name(),
                    isa.name()
                ));
                let mut cfg = tiny_cfg(kind, tier);
                cfg.kernel_isa = isa;
                cfg.checkpoint = Some(ckpt.clone());
                let report = TaskTrainer::new(cfg).unwrap().train().unwrap();
                let bits: Vec<u64> = report.losses.iter().map(|l| l.to_bits()).collect();
                runs.push((bits, std::fs::read(&ckpt).unwrap()));
            }
            assert_eq!(
                runs[1].0,
                runs[0].0,
                "{} {}: loss trace diverged across ISAs",
                kind.name(),
                tier.name()
            );
            assert_eq!(
                runs[1].1,
                runs[0].1,
                "{} {}: checkpoint bytes diverged across ISAs",
                kind.name(),
                tier.name()
            );
        }
    }
}

#[test]
fn eval_report_bytes_are_isa_invariant() {
    let dir = test_dir();
    let isas = isa_pair();
    let ckpt = dir.join("eval_isa.tensors");
    let mut cfg = tiny_cfg(TaskKind::Pos, KernelTier::Decoded);
    cfg.checkpoint = Some(ckpt.clone());
    TaskTrainer::new(cfg).unwrap().train().unwrap();

    let models = vec![ckpt];
    for tier in [KernelTier::Decoded, KernelTier::ShiftAdd] {
        let want = build_report_exec(&models, 1, tier, IsaPath::Scalar).unwrap().to_string();
        for &isa in &isas[1..] {
            let got = build_report_exec(&models, 1, tier, isa).unwrap().to_string();
            assert_eq!(
                got,
                want,
                "{}: eval report bytes diverged under {}",
                tier.name(),
                isa.name()
            );
        }
        // like the tier, the dispatched ISA must never leak into the
        // deterministic report bytes
        for leak in ["scalar", "sse2", "avx2", "kernel_isa"] {
            assert!(!want.contains(leak), "ISA leaked into the report: {leak}");
        }
    }
}

#[test]
fn served_outputs_are_isa_invariant_and_isa_set_is_load_time_only() {
    let isas = isa_pair();

    // lm streamed logits through the streaming forward, both tiers
    for tier in [KernelTier::Decoded, KernelTier::ShiftAdd] {
        let mut bits = Vec::new();
        for &isa in &isas {
            let mut model =
                ServeModel::lm(Arc::new(synthetic_stack(16, 4, 6, 1, 16, 3))).unwrap();
            model.set_kernel_tier(tier).unwrap();
            model.set_kernel_isa(isa).unwrap();
            let mut state = model.stack.new_stream_state();
            let logits = model.stack.forward_from(&[1, 5, 9, 13, 2], &mut state);
            bits.push(
                logits
                    .iter()
                    .flat_map(|row| row.iter().map(|v| v.to_bits()))
                    .collect::<Vec<u32>>(),
            );
        }
        for b in &bits[1..] {
            assert_eq!(b, &bits[0], "{}: streamed lm logits diverged across ISAs", tier.name());
        }
    }

    // mt decode loop on the shift-add tier (the deepest kernel path)
    let dir = test_dir();
    let ckpt = dir.join("serve_isa_mt.tensors");
    let mut cfg = tiny_cfg(TaskKind::Mt, KernelTier::Decoded);
    cfg.checkpoint = Some(ckpt.clone());
    TaskTrainer::new(cfg).unwrap().train().unwrap();
    let src: Vec<usize> = vec![3, 1, 7, 2];
    let mut results = Vec::new();
    for &isa in &isas {
        let mut model = ServeModel::load(&ckpt).expect("mt checkpoint loads");
        model.set_kernel_tier(KernelTier::ShiftAdd).expect("exclusive at load time");
        model.set_kernel_isa(isa).expect("exclusive at load time");
        let (tokens, score) = model.reference_greedy_decode(&src, 8).unwrap();
        results.push((tokens, score.to_bits()));
    }
    for r in &results[1..] {
        assert_eq!(r, &results[0], "mt decode diverged across ISAs");
    }

    // once the stacks are shared, switching the ISA must refuse just
    // like switching the tier does
    let mut model = ServeModel::lm(Arc::new(synthetic_stack(16, 4, 6, 1, 16, 3))).unwrap();
    let _alias = model.stack.clone();
    let err = model.set_kernel_isa(IsaPath::Scalar).expect_err("aliased stack");
    assert!(err.to_string().contains("before the model is shared"), "got: {err}");
}
