//! Tier-1 training smoke tests: the pure-rust quantized trainer
//! learns a tiny char-LM from scratch (no PJRT, no artifacts — this is
//! the offline counterpart of `e2e_train.rs`), stays finite under
//! dynamic loss scaling, and its checkpoints serve bit-identically.

use floatsd_lstm::lstm::model::{build_tiny_from_params, ParamBag};
use floatsd_lstm::qmath::KernelTier;
use floatsd_lstm::tensorfile::read_tensors;
use floatsd_lstm::train::{TrainConfig, Trainer};

fn smoke_cfg() -> TrainConfig {
    TrainConfig {
        vocab: 48,
        dim: 12,
        hidden: 16,
        layers: 1,
        batch: 4,
        seq: 12,
        steps: 160,
        lr: 0.4,
        momentum: 0.9,
        seed: 7,
        loss_scale: 1024.0,
        clip_norm: None,
        log_every: 0,
        threads: 1,
        checkpoint: None,
        trace: None,
        trace_every: 1,
        kernel_tier: KernelTier::Decoded,
        kernel_isa: floatsd_lstm::qmath::IsaPath::detect(),
    }
}

#[test]
fn char_lm_loss_drops_and_checkpoint_serves_bit_identically() {
    let mut trainer = Trainer::new(smoke_cfg()).expect("valid config");
    let report = trainer.train().expect("training");
    for (s, &l) in report.losses.iter().enumerate() {
        assert!(l.is_finite(), "loss went non-finite at step {s}");
    }
    let head: f64 = report.losses[..15].iter().sum::<f64>() / 15.0;
    let n = report.losses.len();
    let tail: f64 = report.losses[n - 15..].iter().sum::<f64>() / 15.0;
    assert!(
        tail < head * 0.95,
        "offline quantized training did not learn: {head:.4} -> {tail:.4}"
    );
    assert!(report.steps_applied > 100, "most steps must apply at scale 1024");

    // checkpoint → serve-side stack → bit-identical logits
    let dir = std::env::temp_dir().join("fsd_train_offline");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("char_lm.ckpt.tensors");
    trainer.save_checkpoint(&path).expect("save checkpoint");

    let bag = ParamBag::from_tensors(read_tensors(&path).expect("read checkpoint"));
    let served = build_tiny_from_params(&bag).expect("assemble served stack");
    for seq in [vec![1usize, 5, 3, 0, 40, 8], vec![0, 0, 1, 2], vec![47, 23, 11]] {
        let want = trainer.stack.forward(&seq);
        let got = served.forward(&seq);
        assert_eq!(got.len(), want.len());
        for (t, (g, w)) in got.iter().zip(&want).enumerate() {
            for (a, b) in g.iter().zip(w) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "served logits diverge from trainer at t={t}"
                );
            }
        }
    }
}

#[test]
fn training_is_deterministic_under_a_fixed_seed() {
    let mut cfg = smoke_cfg();
    cfg.steps = 25;
    let mut a = Trainer::new(cfg.clone()).expect("valid config");
    let mut b = Trainer::new(cfg).expect("valid config");
    let ra = a.train().expect("run a");
    let rb = b.train().expect("run b");
    assert_eq!(ra.losses.len(), rb.losses.len());
    for (s, (la, lb)) in ra.losses.iter().zip(&rb.losses).enumerate() {
        assert_eq!(la.to_bits(), lb.to_bits(), "loss diverged at step {s}");
    }
    assert_eq!(ra.final_scale, rb.final_scale);
}

#[test]
fn dynamic_loss_scaling_recovers_from_an_oversized_scale() {
    let mut cfg = smoke_cfg();
    cfg.steps = 80;
    // absurd initial scale: scaled gradients overflow the FP8 grid, so
    // the scaler must skip + halve until updates apply again — and the
    // model (only touched by applied steps) must stay finite throughout
    cfg.loss_scale = 1e12;
    let mut trainer = Trainer::new(cfg).expect("valid config");
    let report = trainer.train().expect("training");
    assert!(report.steps_skipped > 0, "oversized scale must trigger skips");
    assert!(report.final_scale < 1e12, "scale must back off");
    assert!(
        report.steps_applied > 0,
        "scaler never recovered: final scale {}",
        report.final_scale
    );
    for &l in &report.losses {
        assert!(l.is_finite());
    }
}
