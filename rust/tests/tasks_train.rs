//! Tier-1 multi-task training smoke tests — the `tasks/` counterpart
//! of `train_offline.rs`, mirroring the PR acceptance criteria: POS
//! and NLI training must reduce held-out eval loss ≥ 5% from init
//! under the full quantization scheme, task training is
//! bit-deterministic in the seed, checkpoints evaluate bit-identically
//! after a save → load round trip, and the `floatsd-lstm eval` report
//! is byte-deterministic while covering all four tasks.
//!
//! Sizes are miniatures of the presets, tuned so the margins are wide
//! (the float-precision reference of each task clears the 5% bar by
//! >10x at these step counts).

use floatsd_lstm::tasks::eval::{build_report, evaluate_checkpoint};
use floatsd_lstm::tasks::{TaskConfig, TaskKind, TaskTrainer};

fn pos_cfg() -> TaskConfig {
    let mut cfg = TaskConfig::preset(TaskKind::Pos);
    cfg.vocab = 96;
    cfg.n_classes = 8;
    cfg.dim = 12;
    cfg.hidden = 16;
    cfg.batch = 6;
    cfg.seq = 10;
    cfg.steps = 120;
    cfg.lr = 0.3;
    cfg.momentum = 0.9;
    cfg.seed = 7;
    cfg.eval_batches = 4;
    cfg.log_every = 0;
    cfg.checkpoint = None;
    cfg
}

fn nli_cfg() -> TaskConfig {
    let mut cfg = TaskConfig::preset(TaskKind::Nli);
    cfg.vocab = 40;
    cfg.dim = 12;
    cfg.hidden = 16;
    cfg.batch = 10;
    cfg.seq = 6;
    cfg.steps = 250;
    cfg.lr = 0.3;
    cfg.momentum = 0.9;
    cfg.seed = 7;
    cfg.eval_batches = 4;
    cfg.log_every = 0;
    cfg.checkpoint = None;
    cfg
}

#[test]
fn pos_training_reduces_eval_loss_5_percent() {
    let mut trainer = TaskTrainer::new(pos_cfg()).expect("build pos task");
    let report = trainer.train().expect("train");
    for (s, &l) in report.losses.iter().enumerate() {
        assert!(l.is_finite(), "loss went non-finite at step {s}");
    }
    let (e0, e1) = (&report.eval_init, &report.eval_final);
    assert!(
        e1.loss < e0.loss * 0.95,
        "pos eval loss did not drop 5%: {:.4} -> {:.4}",
        e0.loss,
        e1.loss
    );
    assert!(
        e1.metric > e0.metric,
        "tag accuracy should improve: {:.3} -> {:.3}",
        e0.metric,
        e1.metric
    );
    assert!(report.steps_applied > 80, "most steps must apply: {}", report.steps_applied);
}

#[test]
fn nli_training_reduces_eval_loss_5_percent() {
    let mut trainer = TaskTrainer::new(nli_cfg()).expect("build nli task");
    let report = trainer.train().expect("train");
    for (s, &l) in report.losses.iter().enumerate() {
        assert!(l.is_finite(), "loss went non-finite at step {s}");
    }
    let (e0, e1) = (&report.eval_init, &report.eval_final);
    assert!(
        e1.loss < e0.loss * 0.95,
        "nli eval loss did not drop 5%: {:.4} -> {:.4}",
        e0.loss,
        e1.loss
    );
    assert!(report.steps_applied > 180, "most steps must apply: {}", report.steps_applied);
}

#[test]
fn mt_training_improves_held_out_eval() {
    let mut cfg = TaskConfig::preset(TaskKind::Mt);
    cfg.vocab = 24;
    cfg.vocab_tgt = 24;
    cfg.dim = 10;
    cfg.hidden = 16;
    cfg.batch = 4;
    cfg.seq = 6;
    cfg.steps = 80;
    cfg.seed = 7;
    cfg.eval_batches = 2;
    cfg.log_every = 0;
    cfg.checkpoint = None;
    let mut trainer = TaskTrainer::new(cfg).expect("build mt task");
    let report = trainer.train().expect("train");
    for &l in &report.losses {
        assert!(l.is_finite());
    }
    let (e0, e1) = (&report.eval_init, &report.eval_final);
    // the teacher-forced decoder learns the skewed target marginal
    // quickly (the float reference drops ~15% here); require a clear
    // improvement without pinning the exact rate
    assert!(
        e1.loss < e0.loss * 0.98,
        "mt eval loss did not improve: {:.4} -> {:.4}",
        e0.loss,
        e1.loss
    );
}

#[test]
fn task_training_is_deterministic_in_the_seed() {
    let mut cfg = pos_cfg();
    cfg.steps = 20;
    let mut a = TaskTrainer::new(cfg.clone()).unwrap();
    let mut b = TaskTrainer::new(cfg).unwrap();
    let ra = a.train().unwrap();
    let rb = b.train().unwrap();
    for (s, (la, lb)) in ra.losses.iter().zip(&rb.losses).enumerate() {
        assert_eq!(la.to_bits(), lb.to_bits(), "loss diverged at step {s}");
    }
    assert_eq!(ra.eval_final.loss.to_bits(), rb.eval_final.loss.to_bits());
    assert_eq!(ra.eval_final.metric.to_bits(), rb.eval_final.metric.to_bits());
    assert_eq!(ra.final_scale, rb.final_scale);
}

#[test]
fn checkpoint_round_trip_evaluates_bit_identically() {
    let dir = std::env::temp_dir().join("fsd_tasks_train_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("pos_roundtrip.tensors");
    let mut cfg = pos_cfg();
    cfg.steps = 15;
    cfg.checkpoint = Some(ckpt.clone());
    let mut trainer = TaskTrainer::new(cfg).unwrap();
    let report = trainer.train().unwrap();

    let (cfg2, eval2) = evaluate_checkpoint(&ckpt, 1).expect("reload checkpoint");
    assert_eq!(cfg2.task, TaskKind::Pos);
    assert_eq!(cfg2.vocab, 96);
    assert_eq!(cfg2.hidden, 16);
    assert_eq!(
        eval2.loss.to_bits(),
        report.eval_final.loss.to_bits(),
        "reloaded checkpoint must evaluate bit-identically: {} vs {}",
        eval2.loss,
        report.eval_final.loss
    );
    assert_eq!(eval2.metric.to_bits(), report.eval_final.metric.to_bits());
}

#[test]
fn mt_checkpoint_round_trip_evaluates_bit_identically() {
    let dir = std::env::temp_dir().join("fsd_tasks_train_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("mt_roundtrip.tensors");
    let mut cfg = TaskConfig::preset(TaskKind::Mt);
    cfg.vocab = 20;
    cfg.vocab_tgt = 20;
    cfg.dim = 8;
    cfg.hidden = 10;
    cfg.batch = 3;
    cfg.seq = 4;
    cfg.steps = 6;
    cfg.seed = 19;
    cfg.eval_batches = 2;
    cfg.log_every = 0;
    cfg.checkpoint = Some(ckpt.clone());
    let mut trainer = TaskTrainer::new(cfg).unwrap();
    let report = trainer.train().unwrap();
    let (cfg2, eval2) = evaluate_checkpoint(&ckpt, 1).expect("reload mt checkpoint");
    assert_eq!(cfg2.task, TaskKind::Mt);
    assert_eq!(
        eval2.loss.to_bits(),
        report.eval_final.loss.to_bits(),
        "enc/dec pair must reload bit-identically"
    );
}

#[test]
fn eval_report_covers_all_four_tasks_and_is_byte_deterministic() {
    let dir = std::env::temp_dir().join("fsd_tasks_train_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("pos_for_report.tensors");
    let mut cfg = pos_cfg();
    cfg.steps = 10;
    cfg.checkpoint = Some(ckpt.clone());
    TaskTrainer::new(cfg).unwrap().train().unwrap();

    let models = vec![ckpt];
    let r1 = build_report(&models, 1).expect("report").to_string();
    let r2 = build_report(&models, 1).expect("report again").to_string();
    assert_eq!(r1, r2, "eval report must be byte-deterministic");

    assert!(r1.contains("\"schema\":\"floatsd-eval-v1\""), "schema tag missing");
    for task in ["\"lm\":", "\"pos\":", "\"nli\":", "\"mt\":"] {
        assert!(r1.contains(task), "report missing {task}: {r1}");
    }
    for metric in ["\"ppl\"", "\"tag_acc\"", "\"cls_acc\""] {
        assert!(r1.contains(metric), "report missing metric {metric}");
    }
    assert!(r1.contains("checkpoint:"), "trained pos entry must cite its checkpoint");
    assert!(r1.contains("\"source\":\"init\""), "untrained tasks must be marked init");
    // the mt entry carries the length-bucketed CE block with every
    // bucket present in fixed order (zero-count buckets included)
    assert!(r1.contains("\"length_buckets\""), "mt entry missing length_buckets: {r1}");
    for label in ["\"1-8\"", "\"9-16\"", "\"17-32\"", "\"33+\""] {
        assert!(r1.contains(label), "length bucket {label} missing from report");
    }
    // exactly one task (mt) reports buckets
    assert_eq!(r1.matches("\"length_buckets\"").count(), 1);
}
