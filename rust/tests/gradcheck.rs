//! Gradient check: the analytic BPTT of the reference cell
//! (`lstm::reference::F32LstmCell::bptt`) against central finite
//! differences, per the precedent of fixed-point RNN training analyses
//! (the numerics must be validated against a full-precision reference
//! before trusting the quantized training path built on the same
//! equation set).
//!
//! The loss is a fixed random linear functional of every hidden output
//! (`L = Σ_t Σ_j p[t][j] · h[t][j]`), which exercises all gate paths
//! and the recurrent carry at every step. Weights are f32; the traced
//! forward/loss run in f64, so FD noise sits far below the 1e-3
//! tolerance.

use floatsd_lstm::lstm::reference::{F32LstmCell, RefDense, RefGrads};
use floatsd_lstm::rng::SplitMix64;

fn rand_cell(d: usize, hidden: usize, rng: &mut SplitMix64) -> F32LstmCell {
    let wx: Vec<f32> = (0..d * 4 * hidden).map(|_| rng.uniform(-0.4, 0.4)).collect();
    let wh: Vec<f32> = (0..hidden * 4 * hidden).map(|_| rng.uniform(-0.4, 0.4)).collect();
    let b: Vec<f32> = (0..4 * hidden).map(|_| rng.uniform(-0.2, 0.2)).collect();
    F32LstmCell::from_jax_layout(d, hidden, &wx, &wh, &b)
}

fn loss(cell: &F32LstmCell, xs: &[Vec<f32>], proj: &[Vec<f64>]) -> f64 {
    let tape = cell.forward_traced(xs);
    let mut l = 0f64;
    for (t, p) in proj.iter().enumerate() {
        for (j, w) in p.iter().enumerate() {
            l += w * tape.h_new[t][j];
        }
    }
    l
}

/// Tensor-level relative error `‖a − fd‖₂ / max(‖fd‖₂, ε)`.
fn rel_err(analytic: &[f64], fd: &[f64]) -> f64 {
    assert_eq!(analytic.len(), fd.len());
    let mut diff = 0f64;
    let mut norm = 0f64;
    for (a, f) in analytic.iter().zip(fd) {
        diff += (a - f) * (a - f);
        norm += f * f;
    }
    diff.sqrt() / norm.sqrt().max(1e-9)
}

fn wx_of(c: &mut F32LstmCell) -> &mut Vec<f32> {
    &mut c.wx
}

fn wh_of(c: &mut F32LstmCell) -> &mut Vec<f32> {
    &mut c.wh
}

fn bias_of(c: &mut F32LstmCell) -> &mut Vec<f32> {
    &mut c.bias
}

/// Central finite difference over every slot of one parameter tensor,
/// selected by the `pick` accessor.
fn fd_tensor(
    cell: &F32LstmCell,
    len: usize,
    xs: &[Vec<f32>],
    proj: &[Vec<f64>],
    pick: fn(&mut F32LstmCell) -> &mut Vec<f32>,
) -> Vec<f64> {
    let eps = 1e-3f64;
    let mut fd = Vec::with_capacity(len);
    for k in 0..len {
        let mut plus = clone_cell(cell);
        let w0 = pick(&mut plus)[k] as f64;
        pick(&mut plus)[k] = (w0 + eps) as f32;
        let wp = pick(&mut plus)[k] as f64;
        let lp = loss(&plus, xs, proj);
        let mut minus = clone_cell(cell);
        pick(&mut minus)[k] = (w0 - eps) as f32;
        let wm = pick(&mut minus)[k] as f64;
        let lm = loss(&minus, xs, proj);
        // use the *actual* f32 step so weight-storage rounding cancels
        fd.push((lp - lm) / (wp - wm));
    }
    fd
}

fn clone_cell(c: &F32LstmCell) -> F32LstmCell {
    F32LstmCell {
        input_dim: c.input_dim,
        hidden: c.hidden,
        wx: c.wx.clone(),
        wh: c.wh.clone(),
        bias: c.bias.clone(),
    }
}

#[test]
fn bptt_matches_central_finite_differences() {
    // ≥3 seeds; hidden sizes include non-multiples of MAC_GROUP (5, 7)
    for &(seed, d, hidden, t_len) in
        &[(1u64, 3usize, 5usize, 6usize), (2, 4, 7, 5), (3, 5, 6, 4)]
    {
        let mut rng = SplitMix64::new(seed);
        let cell = rand_cell(d, hidden, &mut rng);
        let xs: Vec<Vec<f32>> =
            (0..t_len).map(|_| (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect()).collect();
        let proj: Vec<Vec<f64>> = (0..t_len)
            .map(|_| (0..hidden).map(|_| rng.uniform(-1.0, 1.0) as f64).collect())
            .collect();

        let tape = cell.forward_traced(&xs);
        let grads = cell.bptt(&tape, &proj);

        let fd_wx = fd_tensor(&cell, 4 * hidden * d, &xs, &proj, wx_of);
        let e = rel_err(&grads.dwx, &fd_wx);
        assert!(e <= 1e-3, "seed {seed}: dwx rel err {e}");

        let fd_wh = fd_tensor(&cell, 4 * hidden * hidden, &xs, &proj, wh_of);
        let e = rel_err(&grads.dwh, &fd_wh);
        assert!(e <= 1e-3, "seed {seed}: dwh rel err {e}");

        let fd_b = fd_tensor(&cell, 4 * hidden, &xs, &proj, bias_of);
        let e = rel_err(&grads.db, &fd_b);
        assert!(e <= 1e-3, "seed {seed}: db rel err {e}");
    }
}

#[test]
fn bptt_input_cotangents_match_finite_differences() {
    let mut rng = SplitMix64::new(9);
    let (d, hidden, t_len) = (3usize, 5usize, 5usize);
    let cell = rand_cell(d, hidden, &mut rng);
    let xs: Vec<Vec<f32>> =
        (0..t_len).map(|_| (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect()).collect();
    let proj: Vec<Vec<f64>> = (0..t_len)
        .map(|_| (0..hidden).map(|_| rng.uniform(-1.0, 1.0) as f64).collect())
        .collect();
    let tape = cell.forward_traced(&xs);
    let grads = cell.bptt(&tape, &proj);

    let eps = 1e-3f64;
    for t in 0..t_len {
        for k in 0..d {
            let mut xp = xs.clone();
            let x0 = xp[t][k] as f64;
            xp[t][k] = (x0 + eps) as f32;
            let step_p = xp[t][k] as f64;
            let lp = loss(&cell, &xp, &proj);
            let mut xm = xs.clone();
            xm[t][k] = (x0 - eps) as f32;
            let step_m = xm[t][k] as f64;
            let lm = loss(&cell, &xm, &proj);
            let fd = (lp - lm) / (step_p - step_m);
            let a = grads.dx[t][k];
            // mixed tolerance: 1e-3 relative with an absolute floor
            // above the O(eps²) FD truncation noise
            assert!(
                (a - fd).abs() <= 1e-3 * fd.abs() + 1e-5,
                "dx[{t}][{k}]: analytic {a} vs fd {fd}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// task-head gradchecks: dense head + CE on top of the LSTM (the f64
// reference of `tasks::pos` / `tasks::nli`)
// ---------------------------------------------------------------------

fn rand_dense(in_dim: usize, n_out: usize, rng: &mut SplitMix64) -> RefDense {
    RefDense {
        in_dim,
        n_out,
        w: (0..n_out * in_dim).map(|_| rng.uniform(-0.4, 0.4)).collect(),
        b: (0..n_out).map(|_| rng.uniform(-0.2, 0.2)).collect(),
    }
}

fn clone_dense(d: &RefDense) -> RefDense {
    RefDense { in_dim: d.in_dim, n_out: d.n_out, w: d.w.clone(), b: d.b.clone() }
}

/// Loss of the combined model. `targets[t] = None` skips step `t` —
/// dense targets model the tagging head, last-step-only the
/// classification head.
fn head_loss(
    cell: &F32LstmCell,
    dense: &RefDense,
    xs: &[Vec<f32>],
    targets: &[Option<usize>],
) -> f64 {
    let tape = cell.forward_traced(xs);
    let mut l = 0f64;
    for (t, y) in targets.iter().enumerate() {
        if let Some(y) = y {
            let logits = dense.forward(&tape.h_new[t]);
            l += RefDense::ce(&logits, *y).0;
        }
    }
    l
}

/// Analytic gradients of [`head_loss`]: CE → dense backward → BPTT.
fn head_grads(
    cell: &F32LstmCell,
    dense: &RefDense,
    xs: &[Vec<f32>],
    targets: &[Option<usize>],
) -> (RefGrads, Vec<f64>, Vec<f64>) {
    let tape = cell.forward_traced(xs);
    let mut dw = vec![0f64; dense.n_out * dense.in_dim];
    let mut db = vec![0f64; dense.n_out];
    let mut dh_seq = Vec::with_capacity(targets.len());
    for (t, y) in targets.iter().enumerate() {
        match y {
            Some(y) => {
                let logits = dense.forward(&tape.h_new[t]);
                let (_, dl) = RefDense::ce(&logits, *y);
                dh_seq.push(dense.backward(&tape.h_new[t], &dl, &mut dw, &mut db));
            }
            None => dh_seq.push(vec![0f64; dense.in_dim]),
        }
    }
    (cell.bptt(&tape, &dh_seq), dw, db)
}

/// FD over one f32 tensor of the combined model (same actual-f32-step
/// trick as `fd_tensor`).
fn fd_head_tensor(
    cell: &F32LstmCell,
    dense: &RefDense,
    xs: &[Vec<f32>],
    targets: &[Option<usize>],
    len: usize,
    pick_cell: Option<fn(&mut F32LstmCell) -> &mut Vec<f32>>,
    pick_dense: Option<fn(&mut RefDense) -> &mut Vec<f32>>,
) -> Vec<f64> {
    let eps = 1e-3f64;
    let mut fd = Vec::with_capacity(len);
    for k in 0..len {
        let eval = |delta: f64| -> (f64, f64) {
            let mut c = clone_cell(cell);
            let mut d = clone_dense(dense);
            let slot: &mut f32 = match (pick_cell, pick_dense) {
                (Some(p), None) => &mut p(&mut c)[k],
                (None, Some(p)) => &mut p(&mut d)[k],
                _ => unreachable!("exactly one tensor selector"),
            };
            let w0 = *slot as f64;
            *slot = (w0 + delta) as f32;
            let actual = *slot as f64;
            (actual, head_loss(&c, &d, xs, targets))
        };
        let (wp, lp) = eval(eps);
        let (wm, lm) = eval(-eps);
        fd.push((lp - lm) / (wp - wm));
    }
    fd
}

fn dense_w_of(d: &mut RefDense) -> &mut Vec<f32> {
    &mut d.w
}

fn dense_b_of(d: &mut RefDense) -> &mut Vec<f32> {
    &mut d.b
}

/// Tagging head (per-step CE over every position, `tasks::pos`
/// structure): analytic head + BPTT gradients vs central FD, ≤1e-3,
/// multiple seeds.
#[test]
fn tagging_head_matches_finite_differences() {
    for &(seed, d, hidden, n_tags, t_len) in
        &[(21u64, 3usize, 5usize, 4usize, 5usize), (22, 4, 7, 3, 4), (23, 3, 6, 5, 6)]
    {
        let mut rng = SplitMix64::new(seed);
        let cell = rand_cell(d, hidden, &mut rng);
        let dense = rand_dense(hidden, n_tags, &mut rng);
        let xs: Vec<Vec<f32>> =
            (0..t_len).map(|_| (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect()).collect();
        let targets: Vec<Option<usize>> =
            (0..t_len).map(|_| Some(rng.next_below(n_tags as u64) as usize)).collect();

        let (grads, dw, db) = head_grads(&cell, &dense, &xs, &targets);

        let fd_dw = fd_head_tensor(&cell, &dense, &xs, &targets, dw.len(), None, Some(dense_w_of));
        let e = rel_err(&dw, &fd_dw);
        assert!(e <= 1e-3, "seed {seed}: head dw rel err {e}");

        let fd_db = fd_head_tensor(&cell, &dense, &xs, &targets, db.len(), None, Some(dense_b_of));
        let e = rel_err(&db, &fd_db);
        assert!(e <= 1e-3, "seed {seed}: head db rel err {e}");

        let fd_wx =
            fd_head_tensor(&cell, &dense, &xs, &targets, 4 * hidden * d, Some(wx_of), None);
        let e = rel_err(&grads.dwx, &fd_wx);
        assert!(e <= 1e-3, "seed {seed}: dwx through the head, rel err {e}");

        let fd_wh =
            fd_head_tensor(&cell, &dense, &xs, &targets, 4 * hidden * hidden, Some(wh_of), None);
        let e = rel_err(&grads.dwh, &fd_wh);
        assert!(e <= 1e-3, "seed {seed}: dwh through the head, rel err {e}");
    }
}

/// Classification head (loss only at the final step, `tasks::nli`
/// structure): every earlier parameter gradient flows through the
/// recurrence alone — vs central FD, ≤1e-3, multiple seeds.
#[test]
fn classification_head_matches_finite_differences() {
    for &(seed, d, hidden, n_cls, t_len) in
        &[(31u64, 3usize, 5usize, 3usize, 6usize), (32, 4, 6, 3, 5), (33, 3, 7, 4, 4)]
    {
        let mut rng = SplitMix64::new(seed);
        let cell = rand_cell(d, hidden, &mut rng);
        let dense = rand_dense(hidden, n_cls, &mut rng);
        let xs: Vec<Vec<f32>> =
            (0..t_len).map(|_| (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect()).collect();
        let mut targets: Vec<Option<usize>> = vec![None; t_len];
        targets[t_len - 1] = Some(rng.next_below(n_cls as u64) as usize);

        let (grads, dw, db) = head_grads(&cell, &dense, &xs, &targets);
        // the recurrent chain must be live: step-0 input cotangents
        let dx0: f64 = grads.dx[0].iter().map(|g| g * g).sum::<f64>().sqrt();
        assert!(dx0 > 1e-10, "seed {seed}: no gradient reached step 0");

        let fd_dw = fd_head_tensor(&cell, &dense, &xs, &targets, dw.len(), None, Some(dense_w_of));
        let e = rel_err(&dw, &fd_dw);
        assert!(e <= 1e-3, "seed {seed}: head dw rel err {e}");

        let fd_db = fd_head_tensor(&cell, &dense, &xs, &targets, db.len(), None, Some(dense_b_of));
        let e = rel_err(&db, &fd_db);
        assert!(e <= 1e-3, "seed {seed}: head db rel err {e}");

        let fd_wx =
            fd_head_tensor(&cell, &dense, &xs, &targets, 4 * hidden * d, Some(wx_of), None);
        let e = rel_err(&grads.dwx, &fd_wx);
        assert!(e <= 1e-3, "seed {seed}: dwx through recurrence, rel err {e}");

        let fd_wh =
            fd_head_tensor(&cell, &dense, &xs, &targets, 4 * hidden * hidden, Some(wh_of), None);
        let e = rel_err(&grads.dwh, &fd_wh);
        assert!(e <= 1e-3, "seed {seed}: dwh through recurrence, rel err {e}");
    }
}

/// The recurrent terms matter: truncating the recurrent cotangent
/// (zeroing `Whᵀ·dz` feedback) must NOT match finite differences on a
/// multi-step sequence — guards against a silently-wrong BPTT that
/// only gets the within-step terms right.
#[test]
fn recurrent_cotangent_terms_are_load_bearing() {
    let mut rng = SplitMix64::new(4);
    let (d, hidden, t_len) = (3usize, 5usize, 6usize);
    let cell = rand_cell(d, hidden, &mut rng);
    let xs: Vec<Vec<f32>> =
        (0..t_len).map(|_| (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect()).collect();
    // only the LAST step contributes loss: all earlier parameter
    // gradient flow is via recurrence
    let mut proj: Vec<Vec<f64>> = (0..t_len).map(|_| vec![0f64; hidden]).collect();
    for j in 0..hidden {
        proj[t_len - 1][j] = rng.uniform(-1.0, 1.0) as f64;
    }
    let tape = cell.forward_traced(&xs);
    let grads = cell.bptt(&tape, &proj);
    // dx at step 0 can only be non-zero through the recurrent chain
    let dx0_norm: f64 = grads.dx[0].iter().map(|g| g * g).sum::<f64>().sqrt();
    assert!(dx0_norm > 1e-8, "recurrent gradient flow missing (dx[0] = 0)");
    // and it must agree with FD
    let eps = 1e-3f64;
    let k = 0usize;
    let mut xp = xs.clone();
    let x0 = xp[0][k] as f64;
    xp[0][k] = (x0 + eps) as f32;
    let sp = xp[0][k] as f64;
    let mut xm = xs.clone();
    xm[0][k] = (x0 - eps) as f32;
    let sm = xm[0][k] as f64;
    let fd = (loss(&cell, &xp, &proj) - loss(&cell, &xm, &proj)) / (sp - sm);
    assert!(
        (grads.dx[0][k] - fd).abs() <= 1e-3 * fd.abs() + 1e-5,
        "dx[0][{k}] through recurrence: analytic {} vs fd {fd}",
        grads.dx[0][k]
    );
}
