//! Gradient check: the analytic BPTT of the reference cell
//! (`lstm::reference::F32LstmCell::bptt`) against central finite
//! differences, per the precedent of fixed-point RNN training analyses
//! (the numerics must be validated against a full-precision reference
//! before trusting the quantized training path built on the same
//! equation set).
//!
//! The loss is a fixed random linear functional of every hidden output
//! (`L = Σ_t Σ_j p[t][j] · h[t][j]`), which exercises all gate paths
//! and the recurrent carry at every step. Weights are f32; the traced
//! forward/loss run in f64, so FD noise sits far below the 1e-3
//! tolerance.

use floatsd_lstm::lstm::reference::F32LstmCell;
use floatsd_lstm::rng::SplitMix64;

fn rand_cell(d: usize, hidden: usize, rng: &mut SplitMix64) -> F32LstmCell {
    let wx: Vec<f32> = (0..d * 4 * hidden).map(|_| rng.uniform(-0.4, 0.4)).collect();
    let wh: Vec<f32> = (0..hidden * 4 * hidden).map(|_| rng.uniform(-0.4, 0.4)).collect();
    let b: Vec<f32> = (0..4 * hidden).map(|_| rng.uniform(-0.2, 0.2)).collect();
    F32LstmCell::from_jax_layout(d, hidden, &wx, &wh, &b)
}

fn loss(cell: &F32LstmCell, xs: &[Vec<f32>], proj: &[Vec<f64>]) -> f64 {
    let tape = cell.forward_traced(xs);
    let mut l = 0f64;
    for (t, p) in proj.iter().enumerate() {
        for (j, w) in p.iter().enumerate() {
            l += w * tape.h_new[t][j];
        }
    }
    l
}

/// Tensor-level relative error `‖a − fd‖₂ / max(‖fd‖₂, ε)`.
fn rel_err(analytic: &[f64], fd: &[f64]) -> f64 {
    assert_eq!(analytic.len(), fd.len());
    let mut diff = 0f64;
    let mut norm = 0f64;
    for (a, f) in analytic.iter().zip(fd) {
        diff += (a - f) * (a - f);
        norm += f * f;
    }
    diff.sqrt() / norm.sqrt().max(1e-9)
}

fn wx_of(c: &mut F32LstmCell) -> &mut Vec<f32> {
    &mut c.wx
}

fn wh_of(c: &mut F32LstmCell) -> &mut Vec<f32> {
    &mut c.wh
}

fn bias_of(c: &mut F32LstmCell) -> &mut Vec<f32> {
    &mut c.bias
}

/// Central finite difference over every slot of one parameter tensor,
/// selected by the `pick` accessor.
fn fd_tensor(
    cell: &F32LstmCell,
    len: usize,
    xs: &[Vec<f32>],
    proj: &[Vec<f64>],
    pick: fn(&mut F32LstmCell) -> &mut Vec<f32>,
) -> Vec<f64> {
    let eps = 1e-3f64;
    let mut fd = Vec::with_capacity(len);
    for k in 0..len {
        let mut plus = clone_cell(cell);
        let w0 = pick(&mut plus)[k] as f64;
        pick(&mut plus)[k] = (w0 + eps) as f32;
        let wp = pick(&mut plus)[k] as f64;
        let lp = loss(&plus, xs, proj);
        let mut minus = clone_cell(cell);
        pick(&mut minus)[k] = (w0 - eps) as f32;
        let wm = pick(&mut minus)[k] as f64;
        let lm = loss(&minus, xs, proj);
        // use the *actual* f32 step so weight-storage rounding cancels
        fd.push((lp - lm) / (wp - wm));
    }
    fd
}

fn clone_cell(c: &F32LstmCell) -> F32LstmCell {
    F32LstmCell {
        input_dim: c.input_dim,
        hidden: c.hidden,
        wx: c.wx.clone(),
        wh: c.wh.clone(),
        bias: c.bias.clone(),
    }
}

#[test]
fn bptt_matches_central_finite_differences() {
    // ≥3 seeds; hidden sizes include non-multiples of MAC_GROUP (5, 7)
    for &(seed, d, hidden, t_len) in
        &[(1u64, 3usize, 5usize, 6usize), (2, 4, 7, 5), (3, 5, 6, 4)]
    {
        let mut rng = SplitMix64::new(seed);
        let cell = rand_cell(d, hidden, &mut rng);
        let xs: Vec<Vec<f32>> =
            (0..t_len).map(|_| (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect()).collect();
        let proj: Vec<Vec<f64>> = (0..t_len)
            .map(|_| (0..hidden).map(|_| rng.uniform(-1.0, 1.0) as f64).collect())
            .collect();

        let tape = cell.forward_traced(&xs);
        let grads = cell.bptt(&tape, &proj);

        let fd_wx = fd_tensor(&cell, 4 * hidden * d, &xs, &proj, wx_of);
        let e = rel_err(&grads.dwx, &fd_wx);
        assert!(e <= 1e-3, "seed {seed}: dwx rel err {e}");

        let fd_wh = fd_tensor(&cell, 4 * hidden * hidden, &xs, &proj, wh_of);
        let e = rel_err(&grads.dwh, &fd_wh);
        assert!(e <= 1e-3, "seed {seed}: dwh rel err {e}");

        let fd_b = fd_tensor(&cell, 4 * hidden, &xs, &proj, bias_of);
        let e = rel_err(&grads.db, &fd_b);
        assert!(e <= 1e-3, "seed {seed}: db rel err {e}");
    }
}

#[test]
fn bptt_input_cotangents_match_finite_differences() {
    let mut rng = SplitMix64::new(9);
    let (d, hidden, t_len) = (3usize, 5usize, 5usize);
    let cell = rand_cell(d, hidden, &mut rng);
    let xs: Vec<Vec<f32>> =
        (0..t_len).map(|_| (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect()).collect();
    let proj: Vec<Vec<f64>> = (0..t_len)
        .map(|_| (0..hidden).map(|_| rng.uniform(-1.0, 1.0) as f64).collect())
        .collect();
    let tape = cell.forward_traced(&xs);
    let grads = cell.bptt(&tape, &proj);

    let eps = 1e-3f64;
    for t in 0..t_len {
        for k in 0..d {
            let mut xp = xs.clone();
            let x0 = xp[t][k] as f64;
            xp[t][k] = (x0 + eps) as f32;
            let step_p = xp[t][k] as f64;
            let lp = loss(&cell, &xp, &proj);
            let mut xm = xs.clone();
            xm[t][k] = (x0 - eps) as f32;
            let step_m = xm[t][k] as f64;
            let lm = loss(&cell, &xm, &proj);
            let fd = (lp - lm) / (step_p - step_m);
            let a = grads.dx[t][k];
            // mixed tolerance: 1e-3 relative with an absolute floor
            // above the O(eps²) FD truncation noise
            assert!(
                (a - fd).abs() <= 1e-3 * fd.abs() + 1e-5,
                "dx[{t}][{k}]: analytic {a} vs fd {fd}"
            );
        }
    }
}

/// The recurrent terms matter: truncating the recurrent cotangent
/// (zeroing `Whᵀ·dz` feedback) must NOT match finite differences on a
/// multi-step sequence — guards against a silently-wrong BPTT that
/// only gets the within-step terms right.
#[test]
fn recurrent_cotangent_terms_are_load_bearing() {
    let mut rng = SplitMix64::new(4);
    let (d, hidden, t_len) = (3usize, 5usize, 6usize);
    let cell = rand_cell(d, hidden, &mut rng);
    let xs: Vec<Vec<f32>> =
        (0..t_len).map(|_| (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect()).collect();
    // only the LAST step contributes loss: all earlier parameter
    // gradient flow is via recurrence
    let mut proj: Vec<Vec<f64>> = (0..t_len).map(|_| vec![0f64; hidden]).collect();
    for j in 0..hidden {
        proj[t_len - 1][j] = rng.uniform(-1.0, 1.0) as f64;
    }
    let tape = cell.forward_traced(&xs);
    let grads = cell.bptt(&tape, &proj);
    // dx at step 0 can only be non-zero through the recurrent chain
    let dx0_norm: f64 = grads.dx[0].iter().map(|g| g * g).sum::<f64>().sqrt();
    assert!(dx0_norm > 1e-8, "recurrent gradient flow missing (dx[0] = 0)");
    // and it must agree with FD
    let eps = 1e-3f64;
    let k = 0usize;
    let mut xp = xs.clone();
    let x0 = xp[0][k] as f64;
    xp[0][k] = (x0 + eps) as f32;
    let sp = xp[0][k] as f64;
    let mut xm = xs.clone();
    xm[0][k] = (x0 - eps) as f32;
    let sm = xm[0][k] as f64;
    let fd = (loss(&cell, &xp, &proj) - loss(&cell, &xm, &proj)) / (sp - sm);
    assert!(
        (grads.dx[0][k] - fd).abs() <= 1e-3 * fd.abs() + 1e-5,
        "dx[0][{k}] through recurrence: analytic {} vs fd {fd}",
        grads.dx[0][k]
    );
}
