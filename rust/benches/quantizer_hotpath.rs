//! Quantizer hot-path throughput (L3 §Perf target: ≥ 1e8 elem/s for
//! the FloatSD8 quantizer): encode, quantize, fp8 and fp16 rounds.

use floatsd_lstm::benchlib::{bench, black_box};
use floatsd_lstm::formats::{round_f16, round_f8, FLOAT_SD8};
use floatsd_lstm::rng::SplitMix64;

fn main() {
    let mut rng = SplitMix64::new(9);
    let xs: Vec<f32> = (0..65536).map(|_| rng.uniform(-2.0, 2.0)).collect();
    let mut out = vec![0f32; xs.len()];

    let s = bench("floatsd8 quantize 64k", || {
        for (o, &x) in out.iter_mut().zip(&xs) {
            *o = FLOAT_SD8.quantize(x);
        }
        black_box(&out);
    });
    println!("{s}  -> {:.1} M elem/s", s.throughput(xs.len()) / 1e6);

    let s = bench("floatsd8 encode 64k", || {
        let mut acc = 0u32;
        for &x in &xs {
            acc = acc.wrapping_add(FLOAT_SD8.encode(x).0 as u32);
        }
        black_box(acc);
    });
    println!("{s}  -> {:.1} M elem/s", s.throughput(xs.len()) / 1e6);

    let s = bench("fp8 round 64k", || {
        for (o, &x) in out.iter_mut().zip(&xs) {
            *o = round_f8(x);
        }
        black_box(&out);
    });
    println!("{s}  -> {:.1} M elem/s", s.throughput(xs.len()) / 1e6);

    let s = bench("fp16 round 64k", || {
        for (o, &x) in out.iter_mut().zip(&xs) {
            *o = round_f16(x);
        }
        black_box(&out);
    });
    println!("{s}  -> {:.1} M elem/s", s.throughput(xs.len()) / 1e6);
}
