//! Table IV — final metric across the four tasks under {FP32 baseline,
//! FloatSD8 (Table II), FloatSD8 + FP16 master (Table VI)}.
//!
//! FSD_BENCH_DIV (default 4) scales the training length; the full run
//! (div=1) is what EXPERIMENTS.md records. Also prints our Table III
//! (hyperparameters) and the Table II/VI precision settings header.

use floatsd_lstm::benchlib::{results_dir, Csv};
use floatsd_lstm::config::preset_for;
use floatsd_lstm::coordinator::run_suite;
use floatsd_lstm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let div: usize = std::env::var("FSD_BENCH_DIV").ok().and_then(|v| v.parse().ok()).unwrap_or(4);
    let mut rt = Runtime::new("artifacts")?;

    println!("Table III (our scaled hyperparameters):");
    println!("  task  epochs steps/epoch batch");
    for t in ["pos", "nli", "mt", "lm"] {
        let p = preset_for(t);
        let b = rt.manifest.task(t)?.batch;
        println!("  {t:<5} {:>6} {:>11} {:>5}", p.epochs, p.steps_per_epoch, b);
    }
    println!("\nprecision schemes under test: fp32 (baseline), fsd8 (Table II), fsd8m16 (Table VI)");
    println!("running with presets / {div}\n");

    let mut csv = Csv::new(results_dir().join("table4.csv"), "task,metric,fp32,fsd8,fsd8m16");
    println!("{:<6} {:>12} {:>10} {:>10} {:>10}", "task", "metric", "fp32", "fsd8", "fsd8m16");
    for task in ["pos", "nli", "mt", "lm"] {
        let names =
            [format!("{task}_fp32"), format!("{task}_fsd8"), format!("{task}_fsd8m16")];
        let refs: Vec<&str> = names.iter().map(String::as_str).collect();
        let r = run_suite(&mut rt, &refs, div)?;
        println!(
            "{task:<6} {:>12} {:>10.3} {:>10.3} {:>10.3}",
            r[0].metric_name, r[0].best_metric, r[1].best_metric, r[2].best_metric
        );
        csv.row(&[
            task.to_string(),
            r[0].metric_name.clone(),
            format!("{:.4}", r[0].best_metric),
            format!("{:.4}", r[1].best_metric),
            format!("{:.4}", r[2].best_metric),
        ]);
    }
    let path = csv.finish()?;
    println!("\ntable4: wrote {}", path.display());
    println!("paper Table IV: UDPOS 89.05/89.09/89.13, SNLI 79.28/79.32/79.24,");
    println!("                Multi30K 37.02/36.87/37.26, WikiText-2 87.83/98.94/91.06");
    Ok(())
}
