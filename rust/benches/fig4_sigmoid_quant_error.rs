//! Fig. 4 — quantization error of the *one-region* quantized sigmoid
//! (Eq. 7 applied to the whole input range). Writes the error series
//! to results/fig4_sigmoid_quant_error.csv and prints summary rows.

use floatsd_lstm::benchlib::{results_dir, Csv};
use floatsd_lstm::qmath::qsigmoid::{sigmoid_sd8, sigmoid_sd8_one_region};

fn main() -> anyhow::Result<()> {
    let mut csv = Csv::new(
        results_dir().join("fig4_sigmoid_quant_error.csv"),
        "x,sigma,one_region_error,two_region_error",
    );
    let (mut max_pos, mut max_neg) = (0f64, 0f64);
    for i in 0..=3200 {
        let x = -8.0 + i as f32 * 0.005;
        let s = 1.0 / (1.0 + (-x as f64).exp());
        let e1 = (sigmoid_sd8_one_region(x) as f64 - s).abs();
        let e2 = (sigmoid_sd8(x) as f64 - s).abs();
        if x > 0.0 {
            max_pos = max_pos.max(e1);
        } else {
            max_neg = max_neg.max(e1);
        }
        csv.rowf(&[x as f64, s, e1, e2]);
    }
    let path = csv.finish()?;
    println!("fig4: wrote {}", path.display());
    println!("one-region max error:  x>0 {max_pos:.4}   x<=0 {max_neg:.4}");
    println!(
        "paper's point: the positive side error is unbalanced ({:.1}x the negative side)",
        max_pos / max_neg
    );
    assert!(max_pos > 1.5 * max_neg, "Fig. 4 asymmetry must reproduce");
    Ok(())
}
