//! Software-MAC throughput: architectural MAC (`mac_exact`), the
//! bit-level pipeline model, the serial-round ablation, and a plain
//! f32 FMA baseline. This is the L3 hot-path microbench behind the
//! §Perf iteration log.

use floatsd_lstm::benchlib::{bench, black_box};
use floatsd_lstm::formats::{FloatSd8, Fp16, Fp8, FLOAT_SD8};
use floatsd_lstm::hardware::mac_sim::MacPipeline;
use floatsd_lstm::qmath::mac::{mac_exact, mac_serial};
use floatsd_lstm::rng::SplitMix64;

fn main() {
    let mut rng = SplitMix64::new(1);
    let n = 4096;
    let xs: Vec<Fp8> = (0..n).map(|_| Fp8::from_f32(rng.uniform(-4.0, 4.0))).collect();
    let ws: Vec<FloatSd8> = (0..n).map(|_| FLOAT_SD8.encode(rng.uniform(-1.0, 1.0))).collect();
    let xf: Vec<f32> = xs.iter().map(|x| x.to_f32()).collect();
    let wf: Vec<f32> = ws.iter().map(|w| FLOAT_SD8.decode(*w)).collect();

    let groups = n / 4;
    let s = bench("mac_exact (4-pair groups)", || {
        let mut acc = Fp16::ZERO;
        for g in 0..groups {
            acc = mac_exact(acc, &xs[g * 4..g * 4 + 4], &ws[g * 4..g * 4 + 4]);
        }
        black_box(acc);
    });
    println!("{s}  -> {:.1} M MAC-groups/s", s.throughput(groups) / 1e6);

    let s = bench("mac_serial (per-add round)", || {
        let mut acc = Fp16::ZERO;
        for g in 0..groups {
            acc = mac_serial(acc, &xs[g * 4..g * 4 + 4], &ws[g * 4..g * 4 + 4]);
        }
        black_box(acc);
    });
    println!("{s}  -> {:.1} M MAC-groups/s", s.throughput(groups) / 1e6);

    let s = bench("bit-level pipeline model", || {
        let mut acc = Fp16::ZERO;
        for g in 0..groups {
            acc = MacPipeline::compute(acc, &xs[g * 4..g * 4 + 4], &ws[g * 4..g * 4 + 4]);
        }
        black_box(acc);
    });
    println!("{s}  -> {:.1} M MAC-groups/s", s.throughput(groups) / 1e6);

    let s = bench("plain f32 dot (baseline)", || {
        let mut acc = 0f32;
        for i in 0..n {
            acc += xf[i] * wf[i];
        }
        black_box(acc);
    });
    println!("{s}  -> {:.1} M mul-adds/s", s.throughput(n) / 1e6);
}
