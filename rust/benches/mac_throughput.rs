//! Software-MAC throughput: architectural MAC (`mac_exact`), the
//! bit-level pipeline model, the serial-round ablation, a plain f32
//! FMA baseline — plus the matvec/matmul kernel tiers (`decoded` vs
//! `shiftadd`) swept across every host-available SIMD path (`scalar`,
//! `sse2`, `avx2`), whose rows land in `BENCH_train.json` under
//! `kernel_rows` so the decoded-vs-shiftadd and per-ISA trajectories
//! are trackable across PRs. This is the L3 hot-path microbench behind
//! the §Perf iteration log.
//!
//! Run: `cargo bench --bench mac_throughput`
//! Quick (CI) configuration: `FSD_BENCH_QUICK=1` shrinks the kernel
//! matrices so the parity rows still get produced in seconds.

use std::collections::BTreeMap;

use floatsd_lstm::benchlib::{bench, black_box, BenchStats};
use floatsd_lstm::formats::{round_f16, round_f8, FloatSd8, Fp16, Fp8, FLOAT_SD8};
use floatsd_lstm::hardware::mac_sim::MacPipeline;
use floatsd_lstm::qmath::mac::{mac_exact, mac_serial};
use floatsd_lstm::qmath::vector::{matmul_isa, matvec_fast, QMatrix};
use floatsd_lstm::qmath::{IsaPath, KernelTier};
use floatsd_lstm::rng::SplitMix64;
use floatsd_lstm::tensorfile::json::Json;

/// `BENCH_train.json` lands at the repo root (next to CHANGES.md);
/// the kernel rows merge into it instead of clobbering the training
/// rows `train_throughput` writes.
fn bench_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_train.json")
}

/// One kernel-tier row: op + tier + forced ISA + register-tile width
/// + measured rate, with the bit-identical cross-check result recorded
/// alongside the numbers. `tile` is `"t8"`/`"t4"`/`"t1"` — the stream
/// count of the widest tile the run dispatches ("t1" is the pre-SoA
/// scalar path, so old-vs-new tiling stays comparable across PRs).
/// `isa` is the forced SIMD path; every (tier, isa, tile) combination
/// is pinned against the decoded/scalar reference bits.
#[allow(clippy::too_many_arguments)]
fn kernel_row(
    op: &str,
    tier: KernelTier,
    isa: IsaPath,
    tile: &str,
    s: &BenchStats,
    macs: usize,
    identical: bool,
) -> Json {
    let mut m = BTreeMap::new();
    m.insert("op".to_string(), Json::Str(op.to_string()));
    m.insert("tier".to_string(), Json::Str(tier.name().to_string()));
    m.insert("isa".to_string(), Json::Str(isa.name().to_string()));
    m.insert("tile".to_string(), Json::Str(tile.to_string()));
    m.insert("ns_per_call".to_string(), Json::Num(s.ns_per_iter()));
    m.insert("m_macs_per_s".to_string(), Json::Num(s.throughput(macs) / 1e6));
    m.insert("identical".to_string(), Json::Bool(identical));
    Json::Obj(m)
}

fn main() -> anyhow::Result<()> {
    let mut rng = SplitMix64::new(1);
    let n = 4096;
    let xs: Vec<Fp8> = (0..n).map(|_| Fp8::from_f32(rng.uniform(-4.0, 4.0))).collect();
    let ws: Vec<FloatSd8> = (0..n).map(|_| FLOAT_SD8.encode(rng.uniform(-1.0, 1.0))).collect();
    let xf: Vec<f32> = xs.iter().map(|x| x.to_f32()).collect();
    let wf: Vec<f32> = ws.iter().map(|w| FLOAT_SD8.decode(*w)).collect();

    let groups = n / 4;
    let s = bench("mac_exact (4-pair groups)", || {
        let mut acc = Fp16::ZERO;
        for g in 0..groups {
            acc = mac_exact(acc, &xs[g * 4..g * 4 + 4], &ws[g * 4..g * 4 + 4]);
        }
        black_box(acc);
    });
    println!("{s}  -> {:.1} M MAC-groups/s", s.throughput(groups) / 1e6);

    let s = bench("mac_serial (per-add round)", || {
        let mut acc = Fp16::ZERO;
        for g in 0..groups {
            acc = mac_serial(acc, &xs[g * 4..g * 4 + 4], &ws[g * 4..g * 4 + 4]);
        }
        black_box(acc);
    });
    println!("{s}  -> {:.1} M MAC-groups/s", s.throughput(groups) / 1e6);

    let s = bench("bit-level pipeline model", || {
        let mut acc = Fp16::ZERO;
        for g in 0..groups {
            acc = MacPipeline::compute(acc, &xs[g * 4..g * 4 + 4], &ws[g * 4..g * 4 + 4]);
        }
        black_box(acc);
    });
    println!("{s}  -> {:.1} M MAC-groups/s", s.throughput(groups) / 1e6);

    let s = bench("plain f32 dot (baseline)", || {
        let mut acc = 0f32;
        for i in 0..n {
            acc += xf[i] * wf[i];
        }
        black_box(acc);
    });
    println!("{s}  -> {:.1} M mul-adds/s", s.throughput(n) / 1e6);

    // ----- kernel tiers: decoded f32 vs integer shift-add ------------
    let quick = std::env::var("FSD_BENCH_QUICK").is_ok();
    // batch 9 in quick mode: one full 8-stream tile plus a tail lane,
    // so CI exercises the widest tile AND the remainder dispatch
    let (rows_n, cols, batch) = if quick { (64, 64, 9) } else { (512, 256, 8) };
    println!("\nkernel tiers ({rows_n}x{cols} weights, batch {batch}):");

    let src: Vec<f32> = (0..rows_n * cols).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let mut w = QMatrix::from_f32(rows_n, cols, &src);
    let x: Vec<f32> = (0..cols).map(|_| round_f8(rng.uniform(-4.0, 4.0))).collect();
    let xb: Vec<f32> = (0..batch * cols).map(|_| round_f8(rng.uniform(-4.0, 4.0))).collect();
    let bias: Vec<f32> = (0..rows_n).map(|_| round_f16(rng.uniform(-0.5, 0.5))).collect();
    let mut out = vec![0f32; rows_n];
    let mut out_b = vec![0f32; batch * rows_n];

    let mut kernel_rows: Vec<Json> = Vec::new();
    let mut reference: BTreeMap<String, Vec<u32>> = BTreeMap::new();
    let isas: Vec<IsaPath> = [IsaPath::Scalar, IsaPath::Sse2, IsaPath::Avx2]
        .into_iter()
        .filter(|i| i.available())
        .collect();
    for tier in [KernelTier::Decoded, KernelTier::ShiftAdd] {
        w.set_kernel_tier(tier);
        for &isa in &isas {
            w.set_kernel_isa(isa);
            // matvec is the batch-1 path: scalar on every ISA (no lane
            // to fill), so the per-ISA rows pin that forcing an ISA
            // never perturbs it
            let s = bench(&format!("matvec [{} {}]", tier.name(), isa.name()), || {
                matvec_fast(&w, &x, &bias, &mut out);
                black_box(&out);
            });
            println!("{s}  -> {:.1} M MACs/s", s.throughput(rows_n * cols) / 1e6);
            let bits: Vec<u32> = out.iter().map(|v| v.to_bits()).collect();
            let identical =
                reference.entry("matvec".to_string()).or_insert_with(|| bits.clone()) == &bits;
            kernel_rows.push(kernel_row("matvec", tier, isa, "t1", &s, rows_n * cols, identical));
            assert!(
                identical,
                "{} {}: matvec diverged from decoded scalar",
                tier.name(),
                isa.name()
            );

            // forced tiles: t8 is the widest (AVX2 rides quads, SSE2
            // pairs), t4 is PR 7's widest, t1 the original scalar loop;
            // every (tier, isa, tile) must produce the same bits
            for (max_tile, tile) in [(8usize, "t8"), (4usize, "t4"), (1usize, "t1")] {
                let label = format!("matmul x{batch} [{} {} {tile}]", tier.name(), isa.name());
                let s = bench(&label, || {
                    matmul_isa(&w, &xb, batch, &bias, &mut out_b, max_tile, isa);
                    black_box(&out_b);
                });
                println!("{s}  -> {:.1} M MACs/s", s.throughput(batch * rows_n * cols) / 1e6);
                let bits: Vec<u32> = out_b.iter().map(|v| v.to_bits()).collect();
                let identical =
                    reference.entry("matmul".to_string()).or_insert_with(|| bits.clone()) == &bits;
                kernel_rows.push(kernel_row(
                    "matmul",
                    tier,
                    isa,
                    tile,
                    &s,
                    batch * rows_n * cols,
                    identical,
                ));
                assert!(
                    identical,
                    "{} {}: matmul {tile} diverged from decoded scalar t8",
                    tier.name(),
                    isa.name()
                );
            }
        }
    }

    // merge into BENCH_train.json without clobbering the training rows
    let json_path = bench_json_path();
    let mut root = match std::fs::read_to_string(&json_path) {
        Ok(text) => match Json::parse(&text) {
            Ok(Json::Obj(m)) => m,
            _ => BTreeMap::new(),
        },
        Err(_) => BTreeMap::new(),
    };
    let mut shape = BTreeMap::new();
    shape.insert("rows".to_string(), Json::Num(rows_n as f64));
    shape.insert("cols".to_string(), Json::Num(cols as f64));
    shape.insert("batch".to_string(), Json::Num(batch as f64));
    shape.insert("rows_list".to_string(), Json::Arr(kernel_rows));
    root.insert("kernel_rows".to_string(), Json::Obj(shape));
    std::fs::write(&json_path, format!("{}\n", Json::Obj(root)))?;
    println!("\nwrote kernel rows into {}", json_path.display());
    Ok(())
}
