//! Training throughput: wall-clock of the lane-sharded offline
//! trainer across thread counts, at the `paper` preset (10k-vocab LM,
//! 2×256 hidden) by default. Emits a machine-readable
//! `BENCH_train.json` at the repo root (tokens/s, step p50/p99, a
//! thread-scaling curve, and a per-row `identical` flag proving the
//! measured runs were bit-identical to the single-thread run) so the
//! training-side bench trajectory is trackable across PRs, like
//! `BENCH_serve.json` on the serving side.
//!
//! The win mechanism: a truncated-BPTT window is embarrassingly
//! parallel across batch lanes (per-stream bit-identical kernels,
//! per-lane state/tapes/gradients), so the fixed lane shards scale
//! across `std::thread` workers until the fixed-order gradient merge
//! and the single-threaded optimizer update dominate.
//!
//! Run: `cargo bench --bench train_throughput`
//! Quick (CI) configuration: `FSD_BENCH_QUICK=1 cargo bench --bench
//! train_throughput` — default preset, fewer steps, threads {1, 2}.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use floatsd_lstm::benchlib::Percentiles;
use floatsd_lstm::tasks::{TaskConfig, TaskKind, TaskTrainer};
use floatsd_lstm::train::PresetTier;
use floatsd_lstm::tensorfile::json::Json;

/// `BENCH_train.json` lands at the repo root (next to CHANGES.md) so
/// successive PRs overwrite one tracked file, regardless of the cwd
/// cargo was invoked from.
fn bench_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_train.json")
}

fn jnum(v: f64) -> Json {
    Json::Num(v)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("FSD_BENCH_QUICK").is_ok();
    let (tier, steps, thread_counts): (PresetTier, usize, &[usize]) = if quick {
        (PresetTier::Default, 3, &[1, 2])
    } else {
        (PresetTier::Paper, 5, &[1, 2, 4, 8])
    };
    let warmup = 1usize;

    let mut base_cfg = TaskConfig::preset_tier(TaskKind::Lm, tier);
    base_cfg.steps = steps;
    base_cfg.log_every = 0;
    base_cfg.eval_batches = 1;
    base_cfg.checkpoint = None;
    let tokens_per_step = base_cfg.batch * base_cfg.seq;
    println!(
        "train throughput [{} preset]: vocab={} dim={} hidden={}x{} | batch={} seq={} \
         ({} tokens/step, {} measured steps + {} warmup per row)\n",
        tier.name(),
        base_cfg.vocab,
        base_cfg.dim,
        base_cfg.hidden,
        base_cfg.layers,
        base_cfg.batch,
        base_cfg.seq,
        tokens_per_step,
        steps,
        warmup
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut base_tps = 0f64;
    let mut base_losses: Vec<u64> = Vec::new();
    for &threads in thread_counts {
        let mut cfg = base_cfg.clone();
        cfg.threads = threads;
        let mut trainer = TaskTrainer::new(cfg)?;
        for _ in 0..warmup {
            trainer.step();
        }
        let mut samples: Vec<Duration> = Vec::with_capacity(steps);
        let mut losses: Vec<u64> = Vec::with_capacity(steps);
        let t0 = Instant::now();
        for _ in 0..steps {
            let s = Instant::now();
            let out = trainer.step();
            samples.push(s.elapsed());
            losses.push(out.loss.to_bits());
        }
        let wall = t0.elapsed();
        let tps = (steps * tokens_per_step) as f64 / wall.as_secs_f64();
        if threads == thread_counts[0] {
            base_tps = tps;
            base_losses = losses.clone();
        }
        // the determinism contract, re-checked on the measured runs:
        // every thread count walked the identical loss trajectory
        let identical = losses == base_losses;
        let speedup = if base_tps > 0.0 { tps / base_tps } else { 1.0 };
        let p = Percentiles::of(&mut samples);
        println!(
            "threads {threads}: {tps:>9.1} tokens/s ({speedup:.2}x) | step p50 {:.3?} \
             p99 {:.3?} | identical-to-base: {identical}",
            p.p50, p.p99
        );
        let mut m = BTreeMap::new();
        m.insert("threads".to_string(), jnum(threads as f64));
        m.insert("tokens_per_s".to_string(), jnum(tps));
        m.insert("speedup".to_string(), jnum(speedup));
        m.insert("p50_ms".to_string(), jnum(p.p50.as_secs_f64() * 1e3));
        m.insert("p99_ms".to_string(), jnum(p.p99.as_secs_f64() * 1e3));
        m.insert("identical".to_string(), Json::Bool(identical));
        // numerics-health snapshot: loss-scale state + per-matrix
        // FloatSD8 code saturation at the end of the measured run
        m.insert("telemetry".to_string(), trainer.numerics_snapshot());
        rows.push(Json::Obj(m));
    }

    let mut model = BTreeMap::new();
    model.insert("task".to_string(), Json::Str("lm".to_string()));
    model.insert("vocab".to_string(), jnum(base_cfg.vocab as f64));
    model.insert("dim".to_string(), jnum(base_cfg.dim as f64));
    model.insert("hidden".to_string(), jnum(base_cfg.hidden as f64));
    model.insert("layers".to_string(), jnum(base_cfg.layers as f64));
    model.insert("batch".to_string(), jnum(base_cfg.batch as f64));
    model.insert("seq".to_string(), jnum(base_cfg.seq as f64));
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("train_throughput".to_string()));
    root.insert("preset".to_string(), Json::Str(tier.name().to_string()));
    root.insert("model".to_string(), Json::Obj(model));
    root.insert("tokens_per_step".to_string(), jnum(tokens_per_step as f64));
    root.insert("steps_per_row".to_string(), jnum(steps as f64));
    root.insert("rows".to_string(), Json::Arr(rows));
    let json_path = bench_json_path();
    std::fs::write(&json_path, format!("{}\n", Json::Obj(root)))?;
    println!("\nwrote {}", json_path.display());
    Ok(())
}
