//! §V-A claim: PE utilization vs batch size — "with the batch size
//! larger than five, the hardware utilization would reach 100%".
//! Writes results/pe_utilization.csv (batch, cycles, utilization).

use floatsd_lstm::benchlib::{results_dir, Csv};
use floatsd_lstm::hardware::pe::ProcessingElement;

fn main() -> anyhow::Result<()> {
    let mut csv = Csv::new(results_dir().join("pe_utilization.csv"), "batch,cycles,utilization");
    println!("batch | cycles | utilization   (64x256 matvec per lane)");
    for batch in 1..=12usize {
        let pe = ProcessingElement::new(batch);
        let s = pe.schedule_cycles(64, 256, batch);
        println!("{batch:>5} | {:>6} | {:>10.1}%", s.cycles, s.utilization * 100.0);
        csv.rowf(&[batch as f64, s.cycles as f64, s.utilization]);
    }
    let path = csv.finish()?;
    println!("pe_utilization: wrote {}", path.display());
    let full = ProcessingElement::new(5).schedule_cycles(64, 256, 5);
    assert!(full.utilization > 0.99, "batch-5 must reach ~100% (paper §V-A)");
    Ok(())
}
