//! Table V — the WikiText-2 activation-precision ablation: five
//! (first-layer, last-layer, other-layers) settings on the LM task.
//! FSD_BENCH_DIV (default 4) scales training length.

use floatsd_lstm::benchlib::{results_dir, Csv};
use floatsd_lstm::coordinator::run_suite;
use floatsd_lstm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let div: usize = std::env::var("FSD_BENCH_DIV").ok().and_then(|v| v.parse().ok()).unwrap_or(4);
    let mut rt = Runtime::new("artifacts")?;

    // Table V rows: (first, last, other) — ab1 == fsd8 (all FP8)
    let rows = [
        ("lm_ab1", "FP8", "FP8", "FP8"),
        ("lm_ab2", "FP16", "FP16", "FP16"),
        ("lm_ab3", "FP8", "FP16", "FP8"),
        ("lm_ab4", "FP16", "FP8", "FP8"),
        ("lm_ab5", "FP16", "FP16", "FP8"),
    ];
    let names: Vec<&str> = rows.iter().map(|r| r.0).collect();
    println!("Table V (LM task, presets / {div}):");
    let results = run_suite(&mut rt, &names, div)?;

    let mut csv = Csv::new(
        results_dir().join("table5.csv"),
        "artifact,first_layer,last_layer,other_layers,perplexity",
    );
    println!("{:<8} {:>6} {:>6} {:>7} {:>12}", "row", "first", "last", "other", "perplexity");
    for (r, (name, first, last, other)) in results.iter().zip(&rows) {
        println!("{name:<8} {first:>6} {last:>6} {other:>7} {:>12.3}", r.best_metric);
        csv.row(&[
            name.to_string(), first.to_string(), last.to_string(),
            other.to_string(), format!("{:.4}", r.best_metric),
        ]);
    }
    let path = csv.finish()?;
    println!("\ntable5: wrote {}", path.display());
    println!("paper Table V ppl: 98.94 / 88.92 / 89.87 / 99.81 / 89.59");
    println!("(shape criterion: last-layer FP16 rows ≈ all-FP16 row; last-layer FP8 rows degrade)");
    Ok(())
}
