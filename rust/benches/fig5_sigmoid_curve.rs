//! Fig. 5 — σ(x) vs the two-region quantized σ (Eq. 8) on x ∈ [0, 8].
//! Writes results/fig5_sigmoid_curve.csv.

use floatsd_lstm::benchlib::{results_dir, Csv};
use floatsd_lstm::qmath::qsigmoid::{sigmoid_sd8, SigmoidLut};

fn main() -> anyhow::Result<()> {
    let mut csv = Csv::new(
        results_dir().join("fig5_sigmoid_curve.csv"),
        "x,sigma,quantized_sigma",
    );
    let mut max_err = 0f64;
    for i in 0..=1600 {
        let x = i as f32 * 0.005;
        let s = 1.0 / (1.0 + (-x as f64).exp());
        let q = sigmoid_sd8(x) as f64;
        max_err = max_err.max((q - s).abs());
        csv.rowf(&[x as f64, s, q]);
    }
    let path = csv.finish()?;
    println!("fig5: wrote {}", path.display());
    println!("two-region max error on [0,8]: {max_err:.4}");
    let lut = SigmoidLut::build();
    println!(
        "merged σ+Q LUT: {} non-zero entries (paper §III-C: 42)",
        lut.nonzero_entries()
    );
    assert_eq!(lut.nonzero_entries(), 42);
    Ok(())
}
