//! Fig. 6 — training curves for the four tasks, FP32 baseline vs the
//! proposed FloatSD8 scheme, on identical data streams.
//!
//! Heavy target: by default runs the presets divided by FSD_BENCH_DIV
//! (default 4). Set FSD_BENCH_DIV=1 for the full Fig. 6 regeneration
//! (recorded in EXPERIMENTS.md). Curves land in results/curves/*.csv
//! (one file per artifact — these ARE the Fig. 6 series).

use floatsd_lstm::coordinator::{run_suite};
use floatsd_lstm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let div: usize = std::env::var("FSD_BENCH_DIV").ok().and_then(|v| v.parse().ok()).unwrap_or(4);
    let mut rt = Runtime::new("artifacts")?;
    println!("fig6: presets / {div} (FSD_BENCH_DIV to change)");
    for task in ["pos", "nli", "mt", "lm"] {
        let names = [format!("{task}_fp32"), format!("{task}_fsd8")];
        let refs: Vec<&str> = names.iter().map(String::as_str).collect();
        let results = run_suite(&mut rt, &refs, div)?;
        println!("\n--- Fig. 6 ({task}) ---");
        println!("epoch | {:>12} | {:>12}", names[0], names[1]);
        let n = results[0].curve.len();
        for e in 0..n {
            println!(
                "{e:>5} | {:>12.3} | {:>12.3}",
                results[0].curve[e].eval_metric, results[1].curve[e].eval_metric
            );
        }
    }
    println!("\nfig6: per-epoch CSVs in results/curves/");
    Ok(())
}
