//! Table VII — area/power of the FP32 MAC vs the FloatSD8 MAC at 40 nm
//! / 400 MHz, from the gate-level cost model (EDA substitution,
//! DESIGN.md §4). Writes results/table7.csv with the full component
//! breakdown.

use floatsd_lstm::benchlib::{results_dir, Csv};
use floatsd_lstm::hardware::cost;

fn main() -> anyhow::Result<()> {
    let (fp32, fsd8, ar, pr) = cost::table7();
    let mut csv = Csv::new(
        results_dir().join("table7.csv"),
        "design,component,ge,area_um2,power_mw",
    );
    for r in [&fp32, &fsd8] {
        println!("\n{} — total {:.0} GE", r.name, r.total_ge());
        for c in &r.components {
            println!("  {:<28} {:>9.0} GE", c.name, c.ge);
            csv.row(&[
                r.name.to_string(),
                c.name.to_string(),
                format!("{:.0}", c.ge),
                format!("{:.1}", c.ge * cost::GE_AREA_UM2),
                format!("{:.4}", c.ge * c.activity * cost::PWR_UW_PER_GE_MHZ * cost::FREQ_MHZ / 1000.0),
            ]);
        }
        csv.row(&[
            r.name.to_string(), "TOTAL".into(),
            format!("{:.0}", r.total_ge()),
            format!("{:.1}", r.area_um2()),
            format!("{:.4}", r.power_mw()),
        ]);
    }
    println!("\nTable VII (40nm CMOS, period 2.5ns):");
    println!("  {:<22} {:>10} {:>10}", "Type", "Area µm²", "Power mW");
    println!("  {:<22} {:>10.0} {:>10.3}", "FP32", fp32.area_um2(), fp32.power_mw());
    println!("  {:<22} {:>10.0} {:>10.3}", "FloatSD8", fsd8.area_um2(), fsd8.power_mw());
    println!("  measured ratios: {ar:.2}x area, {pr:.2}x power");
    println!("  paper:           7.66x area, 5.75x power (26661/3479 µm², 2.920/0.508 mW)");
    let path = csv.finish()?;
    println!("table7: wrote {}", path.display());
    Ok(())
}
