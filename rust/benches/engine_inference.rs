//! Rust inference-engine throughput: quantized engine vs FP32
//! reference engine on an LM-shaped stack, plus the weight-memory
//! footprint comparison (the paper's bandwidth argument §III-E).

use floatsd_lstm::benchlib::{bench, black_box};
use floatsd_lstm::lstm::cell::{CellScratch, QLstmCell};
use floatsd_lstm::lstm::reference::F32LstmCell;
use floatsd_lstm::rng::SplitMix64;

fn main() {
    let (d, h) = (64, 128);
    let mut rng = SplitMix64::new(3);
    let wx: Vec<f32> = (0..d * 4 * h).map(|_| rng.uniform(-0.3, 0.3)).collect();
    let wh: Vec<f32> = (0..h * 4 * h).map(|_| rng.uniform(-0.3, 0.3)).collect();
    let b: Vec<f32> = (0..4 * h).map(|_| rng.uniform(-0.1, 0.1)).collect();
    let qcell = QLstmCell::from_jax_layout(d, h, &wx, &wh, &b);
    let rcell = F32LstmCell::from_jax_layout(d, h, &wx, &wh, &b);
    let x: Vec<f32> = (0..d).map(|_| floatsd_lstm::formats::round_f8(rng.uniform(-1.0, 1.0))).collect();

    let mut qh = vec![0f32; h];
    let mut qc = vec![0f32; h];
    let mut scratch = CellScratch::new(h);
    let s = bench("quantized cell step (D=64,H=128)", || {
        qcell.step(&x, &mut qh, &mut qc, &mut scratch);
        black_box(&qh);
    });
    let flops = (d + h) * 4 * h * 2;
    println!("{s}  -> {:.2} M tok-steps/s, {:.2} GFLOP-equiv/s",
             s.throughput(1) / 1e6, s.throughput(flops) / 1e9);

    let mut rh = vec![0f32; h];
    let mut rc = vec![0f32; h];
    let s2 = bench("fp32 reference cell step", || {
        rcell.step(&x, &mut rh, &mut rc);
        black_box(&rh);
    });
    println!("{s2}  -> {:.2} M tok-steps/s", s2.throughput(1) / 1e6);
    println!(
        "quantized/fp32 software slowdown: {:.2}x (hardware wins {:.1}x area instead — Table VII)",
        s.ns_per_iter() / s2.ns_per_iter(),
        7.66
    );
    let bytes_sd8 = qcell.wx.storage_bytes() + qcell.wh.storage_bytes();
    println!("weight memory: {} B FloatSD8 vs {} B FP32 (4x IO-bandwidth saving)",
             bytes_sd8, bytes_sd8 * 4);
}
