//! Serving throughput: batched `step_batch` tokens/s vs the unbatched
//! per-sequence engine, across micro-batch sizes, plus the full
//! scheduler/worker server end-to-end and a per-task row for each of
//! the four heads the task-generic engine serves (the mt row measures
//! the decode loop — decoded tokens/s). Writes
//! `results/serve_throughput.csv` (batch, tokens_per_s, speedup) and a
//! machine-readable `BENCH_serve.json` at the repo root (tokens/s +
//! p50/p99 per batch size, server end-to-end rows — one per
//! `(workers, max_batch, kernel tier, kernel isa)` with a
//! `kernel_profile` block of per-shape-class decoded-vs-shiftadd wall
//! time split by dispatched ISA — and per-task rows) so the bench
//! trajectory is trackable across PRs. When the host's widest ISA is
//! plain `scalar` the SIMD rows are skipped (they would duplicate the
//! scalar rows bit for bit).
//!
//! The win mechanism: the weight-stationary `matmul_fast` streams each
//! decoded weight row once per micro-batch instead of once per stream,
//! and the flat `StackScratch` removes the sequential path's per-token
//! `Vec` allocations.
//!
//! Run: `cargo bench --bench serve_throughput`

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use floatsd_lstm::benchlib::{bench, black_box, results_dir, BenchStats, Csv};
use floatsd_lstm::lstm::synthetic_stack;
use floatsd_lstm::qmath::{IsaPath, KernelTier};
use floatsd_lstm::rng::SplitMix64;
use floatsd_lstm::serve::demo::{drive_load, drive_task_load};
use floatsd_lstm::serve::{DecodeParams, ServeConfig, ServeModel, Server};
use floatsd_lstm::tasks::TaskKind;
use floatsd_lstm::telemetry::serve_trace::kernel_profile_json;
use floatsd_lstm::telemetry::ServeTraceSink;
use floatsd_lstm::tensorfile::json::Json;

/// `BENCH_serve.json` lands at the repo root (next to CHANGES.md) so
/// successive PRs overwrite one tracked file, regardless of the cwd
/// cargo was invoked from.
fn bench_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_serve.json")
}

fn jnum(v: f64) -> Json {
    Json::Num(v)
}

/// One batch-size row: throughput plus per-iteration latency tails.
fn batch_row(batch: usize, stats: &BenchStats, tokens_per_s: f64, speedup: f64) -> Json {
    let mut m = BTreeMap::new();
    m.insert("batch".to_string(), jnum(batch as f64));
    m.insert("tokens_per_s".to_string(), jnum(tokens_per_s));
    m.insert("speedup".to_string(), jnum(speedup));
    m.insert("p50_us".to_string(), jnum(stats.median.as_secs_f64() * 1e6));
    m.insert("p99_us".to_string(), jnum(stats.p99.as_secs_f64() * 1e6));
    Json::Obj(m)
}

fn main() -> anyhow::Result<()> {
    let (vocab, dim, hidden, layers) = (256usize, 64usize, 192usize, 2usize);
    let seq_len = 32usize;
    let stack = synthetic_stack(vocab, dim, hidden, layers, vocab, 20200711);
    println!(
        "model: vocab={vocab} dim={dim} hidden={hidden}x{layers} | seq_len={seq_len}\n"
    );

    let mut rng = SplitMix64::new(42);
    let mut csv = Csv::new(results_dir().join("serve_throughput.csv"), "batch,tokens_per_s,speedup");
    let mut json_batches: Vec<Json> = Vec::new();
    let mut json_server: Vec<Json> = Vec::new();

    // ---- baseline: the unbatched per-sequence engine path ------------
    let seqs: Vec<Vec<usize>> = (0..8)
        .map(|_| (0..seq_len).map(|_| rng.next_below(vocab as u64) as usize).collect())
        .collect();
    let mut i = 0;
    let base = bench("unbatched QLstmStack::forward (1 stream)", || {
        black_box(stack.forward(&seqs[i % seqs.len()]));
        i += 1;
    });
    let base_tps = base.throughput(seq_len);
    println!("{base}");
    println!("  -> {base_tps:.0} tokens/s (baseline)\n");
    csv.rowf(&[1.0, base_tps, 1.0]);
    json_batches.push(batch_row(1, &base, base_tps, 1.0));

    // ---- batched kernel path across micro-batch sizes ----------------
    let mut batched8_beats_baseline = None;
    for &batch in &[2usize, 4, 8, 16, 32] {
        // ids[t] = the token every stream feeds at step t
        let ids: Vec<Vec<usize>> = (0..seq_len)
            .map(|_| (0..batch).map(|_| rng.next_below(vocab as u64) as usize).collect())
            .collect();
        let mut scratch = stack.scratch(batch);
        let stats = bench(&format!("batched step_batch (B={batch})"), || {
            scratch.reset_states();
            for ids_t in &ids {
                stack.step_batch(ids_t, &mut scratch);
            }
            black_box(&scratch.logits);
        });
        let tps = stats.throughput(batch * seq_len);
        let speedup = tps / base_tps;
        println!("{stats}");
        println!("  -> {tps:.0} tokens/s ({speedup:.2}x vs unbatched)\n");
        csv.rowf(&[batch as f64, tps, speedup]);
        json_batches.push(batch_row(batch, &stats, tps, speedup));
        if batch == 8 {
            batched8_beats_baseline = Some(speedup > 1.0);
        }
    }

    // ---- end-to-end: scheduler + worker pool + session store ----------
    // each row serves through a traced server: the per-row sink holds
    // the telemetry gate open, so the gated kernel wrappers attribute
    // decoded-vs-shiftadd wall time per matvec/matmul shape class
    let shared = Arc::new(stack);
    let isa_auto = IsaPath::detect();
    let mut server_rows = vec![
        (1usize, 16usize, KernelTier::Decoded, IsaPath::Scalar),
        (4, 16, KernelTier::Decoded, IsaPath::Scalar),
        (4, 16, KernelTier::ShiftAdd, IsaPath::Scalar),
        // max-batch 8 caps micro-batches at exactly one 8-stream tile:
        // the wide-tile hot path with no scalar tail, profiled on the
        // shift-add tier
        (4, 8, KernelTier::ShiftAdd, IsaPath::Scalar),
    ];
    if isa_auto != IsaPath::Scalar {
        // per-ISA rows: the same served workload through the widest
        // host SIMD path — bit-identical tokens, different wall time
        server_rows.push((4, 16, KernelTier::Decoded, isa_auto));
        server_rows.push((4, 16, KernelTier::ShiftAdd, isa_auto));
        server_rows.push((4, 8, KernelTier::ShiftAdd, isa_auto));
    }
    for &(workers, max_batch, tier, isa) in &server_rows {
        // a fresh same-seed stack per row — tier and ISA are runtime
        // knobs on the stack, and same-seed rebuilds are bit-identical
        let mut st = synthetic_stack(vocab, dim, hidden, layers, vocab, 20200711);
        st.set_kernel_tier(tier);
        st.set_kernel_isa(isa);
        let st = Arc::new(st);
        let trace_path = results_dir().join(format!(
            "serve_trace_{workers}w_b{max_batch}_{}_{}.jsonl",
            tier.name(),
            isa.name()
        ));
        let sink = Arc::new(ServeTraceSink::create(&trace_path)?);
        let server = Server::start_traced(
            Arc::new(ServeModel::lm(st.clone())?),
            ServeConfig { workers, max_batch, batch_window: Duration::from_micros(200) },
            Some(sink.clone()),
        )?;
        let t0 = std::time::Instant::now();
        let streamed = drive_load(&server, &st, 64, 64, 4);
        let wall = t0.elapsed();
        let agg = server.stats();
        let e2e_tps = streamed as f64 / wall.as_secs_f64();
        println!(
            "server end-to-end ({workers} workers, max-batch {max_batch}, {} {}): \
             {:.0} tokens/s | occupancy {:.2} | latency {}",
            tier.name(),
            isa.name(),
            e2e_tps,
            agg.mean_occupancy,
            agg.latency
        );
        let mut m = BTreeMap::new();
        m.insert("workers".to_string(), jnum(workers as f64));
        m.insert("max_batch".to_string(), jnum(max_batch as f64));
        m.insert("tier".to_string(), Json::Str(tier.name().to_string()));
        m.insert("isa".to_string(), Json::Str(isa.name().to_string()));
        m.insert("tokens_per_s".to_string(), jnum(e2e_tps));
        m.insert("occupancy".to_string(), jnum(agg.mean_occupancy));
        m.insert("p50_us".to_string(), jnum(agg.latency.p50.as_secs_f64() * 1e6));
        m.insert("p99_us".to_string(), jnum(agg.latency.p99.as_secs_f64() * 1e6));
        // deterministic serve counters (per-kind requests/work,
        // occupancy histogram) + wall-clock confined to `timing`
        m.insert("telemetry".to_string(), agg.telemetry_json());
        // shutdown first so batches drained on the way out profile too
        server.shutdown();
        sink.finish()?;
        m.insert("kernel_profile".to_string(), kernel_profile_json(&sink.kernel_profile()));
        json_server.push(Json::Obj(m));
        println!("  trace: {}", trace_path.display());
    }

    // ---- per-task serving rows (incl. the MT decode loop) -------------
    // miniature per-task topologies, served end-to-end through the
    // task-generic engine; the mt row's tokens/s counts *decoded*
    // target tokens — the decode-loop throughput
    println!("\nper-task serving (task-generic engine):");
    let mut json_tasks: Vec<Json> = Vec::new();
    let task_models: Vec<(Arc<ServeModel>, usize, usize)> = vec![
        // (model, sessions, tokens-per-session)
        (Arc::new(ServeModel::lm(shared.clone())?), 32, 32),
        (
            Arc::new(ServeModel::from_parts(
                TaskKind::Pos,
                Arc::new(synthetic_stack(120, 32, 96, 1, 12, 7101)),
                None,
                None,
            )?),
            32,
            32,
        ),
        (
            Arc::new(ServeModel::from_parts(
                TaskKind::Nli,
                Arc::new(synthetic_stack(96, 32, 96, 1, 3, 7102)),
                None,
                None,
            )?),
            32,
            32,
        ),
        (
            Arc::new(ServeModel::from_parts(
                TaskKind::Mt,
                Arc::new(synthetic_stack(64, 32, 96, 1, 1, 7103)),
                Some(Arc::new(synthetic_stack(64, 32, 96, 1, 64, 7104))),
                None,
            )?),
            16,
            16,
        ),
    ];
    let decode = DecodeParams { max_len: 24, beam_width: 1, len_norm: 0.0 };
    for (model, sessions, tokens) in task_models {
        let server = Server::start(
            model.clone(),
            ServeConfig { workers: 4, max_batch: 16, batch_window: Duration::from_micros(200) },
        )?;
        let t0 = std::time::Instant::now();
        let streamed = drive_task_load(&server, &model, sessions, tokens, 4, decode);
        let wall = t0.elapsed();
        let agg = server.stats();
        let tps = streamed as f64 / wall.as_secs_f64();
        let label = if model.task == TaskKind::Mt { "decode tokens/s" } else { "tokens/s" };
        println!(
            "  {:<4} {tps:>10.0} {label} ({} tokens in {:.2?}) | occupancy {:.2} | latency {}",
            model.task.name(),
            streamed,
            wall,
            agg.mean_occupancy,
            agg.latency
        );
        let mut m = BTreeMap::new();
        m.insert("task".to_string(), Json::Str(model.task.name().to_string()));
        m.insert("tokens_per_s".to_string(), jnum(tps));
        m.insert("tokens".to_string(), jnum(streamed as f64));
        m.insert("occupancy".to_string(), jnum(agg.mean_occupancy));
        m.insert("p50_us".to_string(), jnum(agg.latency.p50.as_secs_f64() * 1e6));
        m.insert("p99_us".to_string(), jnum(agg.latency.p99.as_secs_f64() * 1e6));
        if model.task == TaskKind::Mt {
            m.insert("beam_width".to_string(), jnum(decode.beam_width as f64));
            m.insert("decode_len".to_string(), jnum(decode.max_len as f64));
        }
        m.insert("telemetry".to_string(), agg.telemetry_json());
        json_tasks.push(Json::Obj(m));
        server.shutdown();
    }

    let path = csv.finish()?;
    println!("\nwrote {}", path.display());

    // machine-readable trajectory file at the repo root
    let mut model = BTreeMap::new();
    model.insert("vocab".to_string(), jnum(vocab as f64));
    model.insert("dim".to_string(), jnum(dim as f64));
    model.insert("hidden".to_string(), jnum(hidden as f64));
    model.insert("layers".to_string(), jnum(layers as f64));
    model.insert("seq_len".to_string(), jnum(seq_len as f64));
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("serve_throughput".to_string()));
    root.insert("model".to_string(), Json::Obj(model));
    root.insert("baseline_tokens_per_s".to_string(), jnum(base_tps));
    root.insert("batches".to_string(), Json::Arr(json_batches));
    root.insert("server".to_string(), Json::Arr(json_server));
    root.insert("tasks".to_string(), Json::Arr(json_tasks));
    let json_path = bench_json_path();
    std::fs::write(&json_path, format!("{}\n", Json::Obj(root)))?;
    println!("wrote {}", json_path.display());
    match batched8_beats_baseline {
        Some(true) => println!("OK: batched tokens/s exceeds unbatched baseline at batch >= 8"),
        Some(false) => println!("WARN: batch=8 did not beat the unbatched baseline on this host"),
        None => {}
    }
    Ok(())
}
