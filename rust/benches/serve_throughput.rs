//! Serving throughput: batched `step_batch` tokens/s vs the unbatched
//! per-sequence engine, across micro-batch sizes, plus the full
//! scheduler/worker server end-to-end. Writes
//! `results/serve_throughput.csv` (batch, tokens_per_s, speedup).
//!
//! The win mechanism: the weight-stationary `matmul_fast` streams each
//! decoded weight row once per micro-batch instead of once per stream,
//! and the flat `StackScratch` removes the sequential path's per-token
//! `Vec` allocations.
//!
//! Run: `cargo bench --bench serve_throughput`

use std::sync::Arc;
use std::time::Duration;

use floatsd_lstm::benchlib::{bench, black_box, results_dir, Csv};
use floatsd_lstm::lstm::synthetic_stack;
use floatsd_lstm::rng::SplitMix64;
use floatsd_lstm::serve::demo::drive_load;
use floatsd_lstm::serve::{ServeConfig, Server};

fn main() -> anyhow::Result<()> {
    let (vocab, dim, hidden, layers) = (256usize, 64usize, 192usize, 2usize);
    let seq_len = 32usize;
    let stack = synthetic_stack(vocab, dim, hidden, layers, vocab, 20200711);
    println!(
        "model: vocab={vocab} dim={dim} hidden={hidden}x{layers} | seq_len={seq_len}\n"
    );

    let mut rng = SplitMix64::new(42);
    let mut csv = Csv::new(results_dir().join("serve_throughput.csv"), "batch,tokens_per_s,speedup");

    // ---- baseline: the unbatched per-sequence engine path ------------
    let seqs: Vec<Vec<usize>> = (0..8)
        .map(|_| (0..seq_len).map(|_| rng.next_below(vocab as u64) as usize).collect())
        .collect();
    let mut i = 0;
    let base = bench("unbatched QLstmStack::forward (1 stream)", || {
        black_box(stack.forward(&seqs[i % seqs.len()]));
        i += 1;
    });
    let base_tps = base.throughput(seq_len);
    println!("{base}");
    println!("  -> {base_tps:.0} tokens/s (baseline)\n");
    csv.rowf(&[1.0, base_tps, 1.0]);

    // ---- batched kernel path across micro-batch sizes ----------------
    let mut batched8_beats_baseline = None;
    for &batch in &[2usize, 4, 8, 16, 32] {
        // ids[t] = the token every stream feeds at step t
        let ids: Vec<Vec<usize>> = (0..seq_len)
            .map(|_| (0..batch).map(|_| rng.next_below(vocab as u64) as usize).collect())
            .collect();
        let mut scratch = stack.scratch(batch);
        let stats = bench(&format!("batched step_batch (B={batch})"), || {
            scratch.reset_states();
            for ids_t in &ids {
                stack.step_batch(ids_t, &mut scratch);
            }
            black_box(&scratch.logits);
        });
        let tps = stats.throughput(batch * seq_len);
        let speedup = tps / base_tps;
        println!("{stats}");
        println!("  -> {tps:.0} tokens/s ({speedup:.2}x vs unbatched)\n");
        csv.rowf(&[batch as f64, tps, speedup]);
        if batch == 8 {
            batched8_beats_baseline = Some(speedup > 1.0);
        }
    }

    // ---- end-to-end: scheduler + worker pool + session store ----------
    let shared = Arc::new(stack);
    for &(workers, max_batch) in &[(1usize, 16usize), (4, 16)] {
        let server = Server::start(
            shared.clone(),
            ServeConfig { workers, max_batch, batch_window: Duration::from_micros(200) },
        );
        let t0 = std::time::Instant::now();
        let streamed = drive_load(&server, &shared, 64, 64, 4);
        let wall = t0.elapsed();
        let agg = server.stats();
        println!(
            "server end-to-end ({workers} workers, max-batch {max_batch}): \
             {:.0} tokens/s | occupancy {:.2} | latency {}",
            streamed as f64 / wall.as_secs_f64(),
            agg.mean_occupancy,
            agg.latency
        );
        server.shutdown();
    }

    let path = csv.finish()?;
    println!("\nwrote {}", path.display());
    match batched8_beats_baseline {
        Some(true) => println!("OK: batched tokens/s exceeds unbatched baseline at batch >= 8"),
        Some(false) => println!("WARN: batch=8 did not beat the unbatched baseline on this host"),
        None => {}
    }
    Ok(())
}
